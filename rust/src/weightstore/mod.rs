//! The paper's *database* actor (§4.2) — the mailbox decoupling master and
//! workers.  Alain et al. used Redis; we build the equivalent in-tree:
//!
//! * [`MemStore`] — the storage engine: versioned parameter blob +
//!   per-example probability weights with staleness stamps, behind a
//!   `RwLock` (weights) and `Mutex` (params) so concurrent workers never
//!   block each other on reads.
//! * [`server`]/[`client`] — a thread-per-connection TCP layer with a
//!   length-prefixed binary protocol, so master and workers can run as
//!   separate OS processes like the paper's deployment.  Both implement
//!   the same [`WeightStore`] trait, so the coordinator is oblivious to
//!   which transport it talks to ("fire and forget", §4.2).
//!
//! Staleness bookkeeping: every weight push carries the parameter
//! `version` it was computed from; the store stamps it with its own
//! monotonic nanosecond clock.  The master's staleness filter (§B.1) can
//! therefore operate in wall-clock mode (the paper's "4 seconds") or in
//! version mode (exact-mode sanity checks).

pub mod client;
pub mod protocol;
pub mod server;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};
use std::time::Instant;

use anyhow::Result;

/// Everything the master needs to build a proposal distribution.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WeightSnapshot {
    /// Un-normalised probability weights `ω̃_n` (gradient norms).
    pub weights: Vec<f64>,
    /// Store-clock (ns) when each weight was last pushed.
    pub stamps: Vec<u64>,
    /// Parameter version each weight was computed from.
    pub param_versions: Vec<u64>,
}

impl WeightSnapshot {
    pub fn len(&self) -> usize {
        self.weights.len()
    }
    pub fn is_empty(&self) -> bool {
        self.weights.is_empty()
    }
}

/// Store-side aggregate counters (exposed for experiments/monitoring).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StoreStats {
    pub param_pushes: u64,
    pub param_fetches: u64,
    pub weight_pushes: u64,
    pub weights_written: u64,
    pub snapshot_fetches: u64,
    pub grad_applies: u64,
}

/// The master/worker-facing interface of the database actor.
pub trait WeightStore: Send + Sync {
    /// Publish a new parameter blob under a monotonically increasing
    /// version (master → workers).  Pushing a version ≤ current is an
    /// error: versions define staleness.
    fn push_params(&self, version: u64, bytes: Vec<u8>) -> Result<()>;

    /// Fetch the parameter blob if its version is `> than`.  Returns
    /// `None` when the caller is already up to date — workers poll this
    /// cheaply without re-downloading ~76 MB of `paper`-config weights.
    fn fetch_params(&self, than: u64) -> Result<Option<(u64, Vec<u8>)>>;

    /// Latest published parameter version (0 = nothing published yet).
    fn params_version(&self) -> Result<u64>;

    /// Write a contiguous run of weights starting at example `start`,
    /// tagged with the parameter version they were computed from
    /// (workers → master).
    fn push_weights(&self, start: usize, weights: &[f32], param_version: u64) -> Result<()>;

    /// Snapshot all weights + staleness metadata (master).
    fn fetch_weights(&self) -> Result<WeightSnapshot>;

    /// Parameter-server op (ASGD/peer mode, paper §6): apply
    /// ``params -= scale * grad`` elementwise on the stored f32 parameter
    /// blob and bump the version.  The store treats parameters as an
    /// opaque f32 vector — no model knowledge needed.  Errors if no
    /// parameters have been published or sizes mismatch.
    fn apply_grad(&self, scale: f32, grad: &[f32]) -> Result<u64>;

    /// Store-clock in nanoseconds (monotonic, starts near 0).
    fn now(&self) -> Result<u64>;

    /// Aggregate op counters.
    fn stats(&self) -> Result<StoreStats>;
}

struct ParamSlot {
    version: u64,
    bytes: Vec<u8>,
}

/// In-process storage engine (also the backend behind the TCP server).
pub struct MemStore {
    params: Mutex<ParamSlot>,
    weights: RwLock<WeightSnapshot>,
    start: Instant,
    param_pushes: AtomicU64,
    param_fetches: AtomicU64,
    weight_pushes: AtomicU64,
    weights_written: AtomicU64,
    snapshot_fetches: AtomicU64,
    grad_applies: AtomicU64,
}

impl MemStore {
    /// Create a store tracking `n` examples, all weights initialised to
    /// `init_weight` (the paper starts from uniform — every example must
    /// be samplable before the first worker sweep completes).
    pub fn new(n: usize, init_weight: f64) -> Self {
        MemStore {
            params: Mutex::new(ParamSlot {
                version: 0,
                bytes: Vec::new(),
            }),
            weights: RwLock::new(WeightSnapshot {
                weights: vec![init_weight; n],
                stamps: vec![0; n],
                param_versions: vec![0; n],
            }),
            start: Instant::now(),
            param_pushes: AtomicU64::new(0),
            param_fetches: AtomicU64::new(0),
            weight_pushes: AtomicU64::new(0),
            weights_written: AtomicU64::new(0),
            snapshot_fetches: AtomicU64::new(0),
            grad_applies: AtomicU64::new(0),
        }
    }

    pub fn n_examples(&self) -> usize {
        self.weights.read().unwrap().weights.len()
    }
}

impl WeightStore for MemStore {
    fn push_params(&self, version: u64, bytes: Vec<u8>) -> Result<()> {
        let mut slot = self.params.lock().unwrap();
        anyhow::ensure!(
            version > slot.version,
            "parameter version must increase: {} -> {}",
            slot.version,
            version
        );
        slot.version = version;
        slot.bytes = bytes;
        self.param_pushes.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    fn fetch_params(&self, than: u64) -> Result<Option<(u64, Vec<u8>)>> {
        let slot = self.params.lock().unwrap();
        self.param_fetches.fetch_add(1, Ordering::Relaxed);
        if slot.version > than {
            Ok(Some((slot.version, slot.bytes.clone())))
        } else {
            Ok(None)
        }
    }

    fn params_version(&self) -> Result<u64> {
        Ok(self.params.lock().unwrap().version)
    }

    fn push_weights(&self, start: usize, weights: &[f32], param_version: u64) -> Result<()> {
        let now = self.now()?;
        let mut snap = self.weights.write().unwrap();
        anyhow::ensure!(
            start + weights.len() <= snap.weights.len(),
            "weight range {}..{} out of bounds (n = {})",
            start,
            start + weights.len(),
            snap.weights.len()
        );
        for (i, &w) in weights.iter().enumerate() {
            anyhow::ensure!(w.is_finite() && w >= 0.0, "weight {w} invalid at {}", start + i);
            snap.weights[start + i] = w as f64;
            snap.stamps[start + i] = now;
            snap.param_versions[start + i] = param_version;
        }
        self.weight_pushes.fetch_add(1, Ordering::Relaxed);
        self.weights_written
            .fetch_add(weights.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    fn fetch_weights(&self) -> Result<WeightSnapshot> {
        self.snapshot_fetches.fetch_add(1, Ordering::Relaxed);
        Ok(self.weights.read().unwrap().clone())
    }

    fn apply_grad(&self, scale: f32, grad: &[f32]) -> Result<u64> {
        anyhow::ensure!(scale.is_finite(), "scale {scale} invalid");
        let mut slot = self.params.lock().unwrap();
        anyhow::ensure!(slot.version > 0, "no parameters published yet");
        anyhow::ensure!(
            slot.bytes.len() == grad.len() * 4,
            "gradient has {} values, parameter blob holds {}",
            grad.len(),
            slot.bytes.len() / 4
        );
        for (chunk, g) in slot.bytes.chunks_exact_mut(4).zip(grad) {
            let v = f32::from_le_bytes(chunk.try_into().unwrap()) - scale * g;
            chunk.copy_from_slice(&v.to_le_bytes());
        }
        slot.version += 1;
        self.grad_applies.fetch_add(1, Ordering::Relaxed);
        Ok(slot.version)
    }

    fn now(&self) -> Result<u64> {
        Ok(self.start.elapsed().as_nanos() as u64)
    }

    fn stats(&self) -> Result<StoreStats> {
        Ok(StoreStats {
            param_pushes: self.param_pushes.load(Ordering::Relaxed),
            param_fetches: self.param_fetches.load(Ordering::Relaxed),
            weight_pushes: self.weight_pushes.load(Ordering::Relaxed),
            weights_written: self.weights_written.load(Ordering::Relaxed),
            snapshot_fetches: self.snapshot_fetches.load(Ordering::Relaxed),
            grad_applies: self.grad_applies.load(Ordering::Relaxed),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_roundtrip_and_versioning() {
        let s = MemStore::new(4, 1.0);
        assert_eq!(s.params_version().unwrap(), 0);
        assert!(s.fetch_params(0).unwrap().is_none());
        s.push_params(1, vec![1, 2, 3]).unwrap();
        let (v, b) = s.fetch_params(0).unwrap().unwrap();
        assert_eq!((v, b), (1, vec![1, 2, 3]));
        assert!(s.fetch_params(1).unwrap().is_none()); // up to date
        assert!(s.push_params(1, vec![]).is_err()); // must increase
        s.push_params(5, vec![9]).unwrap();
        assert_eq!(s.params_version().unwrap(), 5);
    }

    #[test]
    fn weights_init_and_push() {
        let s = MemStore::new(5, 2.5);
        let snap = s.fetch_weights().unwrap();
        assert_eq!(snap.weights, vec![2.5; 5]);
        s.push_weights(1, &[7.0, 8.0], 3).unwrap();
        let snap = s.fetch_weights().unwrap();
        assert_eq!(snap.weights, vec![2.5, 7.0, 8.0, 2.5, 2.5]);
        assert_eq!(snap.param_versions, vec![0, 3, 3, 0, 0]);
        assert!(snap.stamps[1] > 0);
    }

    #[test]
    fn rejects_out_of_bounds_and_bad_values() {
        let s = MemStore::new(3, 1.0);
        assert!(s.push_weights(2, &[1.0, 1.0], 1).is_err());
        assert!(s.push_weights(0, &[f32::NAN], 1).is_err());
        assert!(s.push_weights(0, &[-1.0], 1).is_err());
    }

    #[test]
    fn stats_count_ops() {
        let s = MemStore::new(3, 1.0);
        s.push_params(1, vec![0]).unwrap();
        s.fetch_params(0).unwrap();
        s.push_weights(0, &[1.0, 2.0], 1).unwrap();
        s.fetch_weights().unwrap();
        let st = s.stats().unwrap();
        assert_eq!(st.param_pushes, 1);
        assert_eq!(st.param_fetches, 1);
        assert_eq!(st.weight_pushes, 1);
        assert_eq!(st.weights_written, 2);
        assert_eq!(st.snapshot_fetches, 1);
    }

    #[test]
    fn apply_grad_is_elementwise_sgd() {
        let s = MemStore::new(2, 1.0);
        // params = [1.0, 2.0, -3.0]
        let mut blob = Vec::new();
        for v in [1.0f32, 2.0, -3.0] {
            blob.extend(v.to_le_bytes());
        }
        s.push_params(1, blob).unwrap();
        let v = s.apply_grad(0.5, &[2.0, -2.0, 4.0]).unwrap();
        assert_eq!(v, 2);
        let (ver, bytes) = s.fetch_params(0).unwrap().unwrap();
        assert_eq!(ver, 2);
        let got: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        assert_eq!(got, vec![0.0, 3.0, -5.0]);
        assert_eq!(s.stats().unwrap().grad_applies, 1);
    }

    #[test]
    fn apply_grad_validates() {
        let s = MemStore::new(2, 1.0);
        assert!(s.apply_grad(0.1, &[1.0]).is_err()); // no params yet
        s.push_params(1, vec![0u8; 8]).unwrap();
        assert!(s.apply_grad(0.1, &[1.0]).is_err()); // size mismatch
        assert!(s.apply_grad(f32::NAN, &[1.0, 1.0]).is_err());
    }

    #[test]
    fn clock_is_monotonic() {
        let s = MemStore::new(1, 0.0);
        let a = s.now().unwrap();
        let b = s.now().unwrap();
        assert!(b >= a);
    }

    #[test]
    fn concurrent_pushers_do_not_lose_writes() {
        use std::sync::Arc;
        let s = Arc::new(MemStore::new(1000, 0.0));
        let mut handles = Vec::new();
        for t in 0..4 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for i in 0..250 {
                    let idx = t * 250 + i;
                    s.push_weights(idx, &[(idx + 1) as f32], 1).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = s.fetch_weights().unwrap();
        for (i, &w) in snap.weights.iter().enumerate() {
            assert_eq!(w, (i + 1) as f64);
        }
    }
}
