//! The paper's *database* actor (§4.2) — the mailbox decoupling master and
//! workers.  Alain et al. used Redis; we build the equivalent in-tree:
//!
//! * [`MemStore`] — the storage engine: versioned parameter blob +
//!   per-example probability weights with staleness stamps.  The weight
//!   table is striped across contiguous [`RwLock`] shards so concurrent
//!   worker pushes to different regions never serialize on one global
//!   write lock, and every write is tagged with a monotonic
//!   **write sequence** so the master can fetch *deltas* instead of full
//!   snapshots.
//! * [`server`]/[`client`] — an event-driven TCP layer (one `poll(2)`
//!   loop over nonblocking sockets, request pipelining, batched writes;
//!   see [`server`] and the [`sys`] shim) with a length-prefixed binary
//!   protocol, so master and workers can run as separate OS processes
//!   like the paper's deployment — and so one server scales to
//!   thousand-connection worker fleets without a thread per socket.
//!   [`client::Client`] (one pooled-or-private connection with desync
//!   poisoning + timeouts) and [`client::ClientPool`] (bounded
//!   connection pool with coalesced delta fetches) both implement the
//!   same [`WeightStore`] trait, so the coordinator is oblivious to
//!   which transport it talks to ("fire and forget", §4.2).
//! * [`faulty::FaultyStore`] — a fault-injection decorator over any
//!   [`WeightStore`]: deterministic (seeded RNG + virtual-time clock)
//!   transient errors, latency, and delta withholding/reordering, so the
//!   staleness regimes the paper argues about are *testable*, not just
//!   runnable.
//! * [`durable::DurableStore`] — the persistent backend: a [`MemStore`]
//!   serving engine journaled to an append-only segment log with periodic
//!   full-snapshot checkpoints, threshold-triggered compaction/GC, and
//!   torn-tail crash recovery.  Disk frames reuse the wire codec
//!   ([`protocol`]), so disk and network stay one format.
//!
//! # Backend matrix
//!
//! | backend                | transport   | durability        | concurrency                                   |
//! |------------------------|-------------|-------------------|-----------------------------------------------|
//! | [`MemStore`]           | in-process  | none (RAM only)   | striped shard `RwLock`s, concurrent push/fetch |
//! | [`client::Client`]     | TCP         | that of the server| one in-flight request per client handle; poisons + reconnects on frame-level errors |
//! | [`client::ClientPool`] | TCP         | that of the server| up to `max_conns` concurrent requests; same-cursor `fetch_weights_since` coalesced into one round-trip |
//! | [`faulty::FaultyStore`]| decorator   | that of the inner | that of the inner (RNG under a mutex)          |
//! | [`durable::DurableStore`] | in-process | crash-consistent journal + snapshots | reads concurrent (inner `MemStore`), writes serialized on the journal lock |
//!
//! All five implement the same [`WeightStore`] trait, so every topology
//! (master/worker sim + live, peer sim + live, remote TCP deployments)
//! composes with every backend — including `FaultyStore` over
//! `DurableStore` for chaos-recovery tests.  The on-disk segment/snapshot
//! format is documented in [`durable`].
//!
//! # Delta / sequence semantics
//!
//! The store keeps one global write-sequence counter.  Each
//! [`WeightStore::push_weights`] call acquires the write locks of *every*
//! shard its run touches (in ascending order — deadlock-free against other
//! writers and the all-shards snapshot reader), claims the next sequence
//! value while holding them, and stamps every written entry with it, so a
//! push is atomic: readers never observe half of one.
//! [`WeightStore::fetch_weights_since`]`(seq)` returns a [`WeightDelta`]
//! containing
//!
//! * every entry whose last write-sequence is `> seq`, and
//! * a new cursor `delta.seq` — the global counter observed *before* the
//!   shards were scanned.
//!
//! Guarantees:
//!
//! * **No lost updates.**  Every write with sequence `<= delta.seq` is
//!   included in the delta (the claim happens under the shard write lock,
//!   so a reader that observed the claimed counter value will block on the
//!   shard until the entries are actually written).
//! * **Idempotent replay.**  Entries carry absolute values (not diffs), so
//!   an entry that races past the cursor may be delivered twice — applying
//!   it twice is harmless.  Replaying deltas from any cursor onto the
//!   snapshot taken at that cursor reconstructs the current table exactly.
//! * **Full fallback.**  `seq == 0` (a fresh consumer), a cursor from
//!   the future (a consumer of a restarted in-memory store), or a cursor
//!   below the **compaction floor** (history folded away by
//!   [`MemStore::compact_before`]) returns the entire table with
//!   `delta.full == true`.  The initial table state carries write
//!   sequence 1, so a consumer that synced a fresh store holds cursor 1 —
//!   never the ambiguous 0 — and all later fetches are incremental.
//!   Consumers protect themselves from the compaction fallback by saving
//!   their cursor ([`WeightStore::save_cursor`]): compaction never folds
//!   at or above the oldest saved cursor.
//!
//! The master's per-step proposal maintenance therefore moves O(changes)
//! bytes and does O(changes · log N) sampler updates, instead of cloning
//! 3×N vectors and rebuilding from scratch every step
//! (see `coordinator::proposal`).
//!
//! Staleness bookkeeping: every weight push carries the parameter
//! `version` it was computed from; the store stamps it with its own
//! monotonic nanosecond clock.  The master's staleness filter (§B.1) can
//! therefore operate in wall-clock mode (the paper's "4 seconds") or in
//! version mode (exact-mode sanity checks).
//!
//! # Layer-wise parameter deltas
//!
//! Parameters get the same O(changes)-vs-O(N) treatment as weights.  The
//! stored blob is split into **named layer chunks** (the publisher keys
//! them off the model manifest — see `model::layer_chunk_name`), each
//! tagged with the params version that last wrote it.
//! [`WeightStore::push_params_layers`] updates only the layers a step
//! actually touched; [`WeightStore::fetch_params_since`]`(v)` returns a
//! [`ParamsDelta`] carrying only layers newer than `v` plus the new
//! version cursor.  The legacy whole-blob ops ([`WeightStore::push_params`],
//! [`WeightStore::fetch_params`]) remain as the bootstrap/opaque path and
//! observe the concatenation of the chunks in layout order.
//!
//! **Params fallback contract** (mirrors the weight cursor contract):
//! `fetch_params_since` returns `None` when the caller is up to date (or
//! nothing is published); otherwise a delta whose `full` flag is set when
//! the caller's version predates the store's retained layer history —
//! version 0 (bootstrap), a version below the **params floor** (a
//! whole-blob publish or full-layout republish resets per-layer history,
//! raising the floor to that version), or a version from the future (a
//! consumer of a restarted store).  A full delta carries the complete
//! layout in order; an incremental one only the dirty layers, applied in
//! place by `model::ParamSet::apply_delta`.  Layer bytes are absolute, so
//! re-delivery is idempotent, exactly like weight deltas.
//! [`WeightStore::apply_grad`] touches every layer and therefore marks
//! the whole layout dirty at the new version.
//!
//! Saved consumer cursors can also be **dropped**
//! ([`WeightStore::drop_cursor`]): a pin from a dead consumer no longer
//! blocks the compaction floor forever — drop it explicitly, or let the
//! durable compactor's optional max-age expiry reap it.
//!
//! # Canonical lock order
//!
//! Every code path that holds more than one of the store's locks at once
//! must acquire them in this order (machine-checked by
//! `cargo run -p xtask -- analyze`, which parses the next line):
//!
//! lock-order: compact_serial -> log -> signal -> cursors -> params -> shards
//!
//! * `compact_serial` — [`durable::DurableStore`]'s compaction serializer;
//!   outermost because one full compaction cycle spans journal writes,
//!   cursor reads, and shard snapshots.
//! * `log` — the durable journal state.  Every mutating op appends under
//!   it *before* applying to the inner [`MemStore`], so it nests outside
//!   all `MemStore` locks.
//! * `signal` — the compactor wake-up channel; taken under `log` by
//!   `after_append` to ring the bell.
//! * `cursors` — the consumer-cursor registry; compaction reads the pin
//!   floor before touching shards.
//! * `params` — the parameter blob/layer table.
//! * `shards` — the striped weight-table `RwLock`s; innermost.  Multi-shard
//!   operations acquire shards in ascending index order (an intra-class
//!   rule the analyzer cannot see — keep it when writing new sweeps).
//!
//! Ad-hoc leaf locks that never nest with the above (a client's `stream`,
//! a peer's `state`, `FaultyStore`'s `rng`, `ClientPool`'s `idle` /
//! `inflight` / per-flight `done` — the pool drops each before taking the
//! next) stay out of the declared chain; the analyzer still folds them
//! into its cycle check.

pub mod client;
pub mod durable;
pub mod faulty;
pub mod protocol;
pub mod segment;
pub mod server;
pub mod sys;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};
use std::time::Instant;

use anyhow::{Context, Result};

/// Everything the master needs to build a proposal distribution.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WeightSnapshot {
    /// Un-normalised probability weights `ω̃_n` (gradient norms).
    pub weights: Vec<f64>,
    /// Store-clock (ns) when each weight was last pushed.
    pub stamps: Vec<u64>,
    /// Parameter version each weight was computed from.
    pub param_versions: Vec<u64>,
}

impl WeightSnapshot {
    pub fn len(&self) -> usize {
        self.weights.len()
    }
    pub fn is_empty(&self) -> bool {
        self.weights.is_empty()
    }
}

/// The incremental counterpart of [`WeightSnapshot`]: the entries written
/// since a caller-provided cursor, in column layout (`indices[i]` was set
/// to `weights[i]`/`stamps[i]`/`param_versions[i]`).
///
/// See the module docs for the cursor contract.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WeightDelta {
    /// New cursor: pass this to the next `fetch_weights_since` call.
    pub seq: u64,
    /// Total number of examples the store tracks (size check for appliers).
    pub n: u64,
    /// True when `entries` cover the whole table (cursor 0 or unservable).
    pub full: bool,
    /// Example indices of the changed entries.
    pub indices: Vec<u64>,
    /// New weight of each changed entry.
    pub weights: Vec<f64>,
    /// Store-clock stamp of each changed entry.
    pub stamps: Vec<u64>,
    /// Parameter version of each changed entry.
    pub param_versions: Vec<u64>,
}

impl WeightDelta {
    /// Number of changed entries carried.
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Overwrite `snap` with this delta's entries.  A `full` delta resizes
    /// the snapshot; an incremental one requires matching sizes.
    ///
    /// All validation happens before any mutation: a malformed delta never
    /// leaves the snapshot half-applied (`ProposalMaintainer::absorb`
    /// keeps its raw mirror only because this call is all-or-nothing).
    pub fn apply_to(&self, snap: &mut WeightSnapshot) -> Result<()> {
        let n = self.n as usize;
        if self.full {
            // Resizing to `n` is only safe because a full delta must carry
            // the whole table (the decoder enforces the same invariant).
            anyhow::ensure!(
                self.indices.len() == n,
                "full delta carries {} entries for a table of {n}",
                self.indices.len()
            );
        } else {
            anyhow::ensure!(
                snap.len() == n,
                "delta tracks {} entries but snapshot holds {}",
                n,
                snap.len()
            );
        }
        anyhow::ensure!(
            self.indices.len() == self.weights.len()
                && self.weights.len() == self.stamps.len()
                && self.stamps.len() == self.param_versions.len(),
            "delta columns disagree on length"
        );
        for &idx in &self.indices {
            anyhow::ensure!(
                (idx as usize) < n,
                "delta index {idx} out of bounds (n = {n})"
            );
        }
        if self.full {
            snap.weights.clear();
            snap.weights.resize(n, 0.0);
            snap.stamps.clear();
            snap.stamps.resize(n, 0);
            snap.param_versions.clear();
            snap.param_versions.resize(n, 0);
        }
        for (k, &idx) in self.indices.iter().enumerate() {
            let i = idx as usize;
            snap.weights[i] = self.weights[k];
            snap.stamps[i] = self.stamps[k];
            snap.param_versions[i] = self.param_versions[k];
        }
        Ok(())
    }

    /// Materialise a `full` delta as a snapshot.
    pub fn to_snapshot(&self) -> Result<WeightSnapshot> {
        anyhow::ensure!(self.full, "to_snapshot requires a full delta");
        let mut snap = WeightSnapshot::default();
        self.apply_to(&mut snap)?;
        Ok(snap)
    }
}

/// One named parameter layer chunk as shipped by a params delta: the
/// layer's full byte payload plus the params version that last wrote it.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LayerChunk {
    /// Layer name (the publisher keys these off the model manifest).
    pub name: String,
    /// Params version that last wrote this layer.
    pub version: u64,
    /// The layer's serialized parameters (absolute, not a diff).
    pub bytes: Vec<u8>,
}

/// The incremental counterpart of the parameter blob: the layers written
/// since a caller-provided version cursor, in layout order.  See the
/// module docs for the params fallback contract.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ParamsDelta {
    /// New cursor: the store's params version at fetch time.
    pub version: u64,
    /// True when `layers` carries the complete layout (bootstrap,
    /// below-floor, or future-cursor fallback); false means only the
    /// dirty layers are present and the caller must already hold the rest.
    pub full: bool,
    /// The shipped layer chunks, in layout order.
    pub layers: Vec<LayerChunk>,
}

impl ParamsDelta {
    /// Number of layer chunks carried.
    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// Total layer payload bytes carried (the O(changes) traffic).
    pub fn payload_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.bytes.len()).sum()
    }

    /// Concatenate a **full** delta's layers into the flat wire blob
    /// ([`WeightStore::fetch_params`] order).
    pub fn to_blob(&self) -> Result<Vec<u8>> {
        anyhow::ensure!(self.full, "to_blob requires a full params delta");
        let mut out = Vec::with_capacity(self.payload_bytes());
        for l in &self.layers {
            out.extend_from_slice(&l.bytes);
        }
        Ok(out)
    }
}

/// Store-side aggregate counters (exposed for experiments/monitoring).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StoreStats {
    pub param_pushes: u64,
    pub param_fetches: u64,
    pub weight_pushes: u64,
    pub weights_written: u64,
    pub snapshot_fetches: u64,
    pub grad_applies: u64,
    /// `fetch_weights_since` calls served.
    pub delta_fetches: u64,
    /// Entries shipped across all delta fetches (the O(changes) traffic).
    pub delta_entries: u64,
    /// `fetch_params_since` calls served.
    pub params_delta_fetches: u64,
    /// Layer chunks shipped across all params delta fetches.
    pub params_delta_layers: u64,
    /// `push_weights` round-trips avoided by client-side run coalescing
    /// (peer mode sorts a minibatch's positions and pushes contiguous runs
    /// in one call).  The store itself cannot observe avoided calls, so
    /// this is folded in by the driver that owns the clients — raw
    /// `WeightStore::stats` reads report 0.
    pub push_calls_saved: u64,
    /// Well-framed but undecodable request frames answered with
    /// `Response::Err` by the TCP server.  A transport-level counter: the
    /// event loop folds it into `Stats` responses (same pattern as the
    /// driver-folded `push_calls_saved`); raw backend `stats` reads
    /// report 0.
    pub protocol_errors: u64,
}

/// The master/worker-facing interface of the database actor.
pub trait WeightStore: Send + Sync {
    /// Publish a new parameter blob under a monotonically increasing
    /// version (master → workers).  Pushing a version ≤ current is an
    /// error: versions define staleness.
    fn push_params(&self, version: u64, bytes: Vec<u8>) -> Result<()>;

    /// Fetch the parameter blob if its version is `> than`.  Returns
    /// `None` when the caller is already up to date — workers poll this
    /// cheaply without re-downloading ~76 MB of `paper`-config weights.
    fn fetch_params(&self, than: u64) -> Result<Option<(u64, Vec<u8>)>>;

    /// Publish named parameter layers under `version` (> current; versions
    /// define staleness, exactly like [`WeightStore::push_params`]).
    ///
    /// `full == true` (re)defines the entire layout from `layers` (names
    /// must be unique and non-empty) and raises the **params floor** to
    /// `version` — per-layer history before a layout definition cannot be
    /// served precisely.  `full == false` updates only the named layers,
    /// which must already exist with the same byte size (a mismatch means
    /// publisher and store disagree on the model config — a hard error,
    /// not a transient).  The first publish on a fresh slot must be full.
    fn push_params_layers(&self, version: u64, full: bool, layers: &[(String, Vec<u8>)])
        -> Result<()>;

    /// Layers written since params version `than` plus the new version
    /// cursor — the incremental parameter fetch.  `None` when the caller
    /// is up to date or nothing is published; otherwise see the module
    /// docs for when the delta degrades to `full` (version 0, below the
    /// params floor, or from the future).  Layer bytes are absolute, so
    /// re-delivery is idempotent; like weight cursors, params version
    /// cursors are per-consumer client-side state.
    fn fetch_params_since(&self, than: u64) -> Result<Option<ParamsDelta>>;

    /// Latest published parameter version (0 = nothing published yet).
    fn params_version(&self) -> Result<u64>;

    /// Write a contiguous run of weights starting at example `start`,
    /// tagged with the parameter version they were computed from
    /// (workers → master).
    fn push_weights(&self, start: usize, weights: &[f32], param_version: u64) -> Result<()>;

    /// Snapshot all weights + staleness metadata (master).
    fn fetch_weights(&self) -> Result<WeightSnapshot>;

    /// Entries written since `seq` plus a new cursor — the incremental
    /// fetch behind both training topologies.  `seq == 0` returns the full
    /// table.  See the module docs for the exact cursor contract.
    ///
    /// **Cursors are per-consumer state.**  The store keeps no registry of
    /// readers: each consumer (master, peer, monitor, …) stores the
    /// `delta.seq` it last absorbed and passes it back on its next call.
    /// Any number of consumers may interleave fetches from different
    /// cursors against concurrent writers; each independently converges on
    /// the same table (entries are absolute values, so re-delivery across
    /// racing fetches is idempotent).  A cursor from a dead consumer costs
    /// the store nothing — there is nothing to GC or time out.
    fn fetch_weights_since(&self, seq: u64) -> Result<WeightDelta>;

    /// Parameter-server op (ASGD/peer mode, paper §6): apply
    /// ``params -= scale * grad`` elementwise on the stored f32 parameter
    /// blob and bump the version.  The store treats parameters as an
    /// opaque f32 vector — no model knowledge needed.  Errors if no
    /// parameters have been published or sizes mismatch.
    fn apply_grad(&self, scale: f32, grad: &[f32]) -> Result<u64>;

    /// Persist/advance a named consumer cursor.
    ///
    /// # Cursor-safety contract (compaction)
    ///
    /// Saved cursors are **compaction pins**: a store that truncates its
    /// write-sequence history ([`MemStore::compact_before`], the durable
    /// compactor) never folds history at or above the *oldest* saved
    /// cursor.  A consumer that saves its cursor after every successful
    /// absorb is therefore guaranteed incremental (`full == false`) deltas
    /// for as long as it lives — and, on a durable backend, across store
    /// restarts too.  A consumer that never saves stays *correct* but
    /// unprotected: compaction may advance past its private cursor, and
    /// its next fetch degrades to the full-table fallback.  Saving a
    /// `seq` beyond the current write sequence clamps to the current
    /// sequence.
    fn save_cursor(&self, name: &str, seq: u64) -> Result<()>;

    /// Last saved cursor for `name` (`None` = unknown consumer) — the
    /// crash-resume entry point: a restarted consumer that checkpointed
    /// its own mirror can continue incrementally from here instead of
    /// paying an O(N) resync.
    fn load_cursor(&self, name: &str) -> Result<Option<u64>>;

    /// Discard a saved consumer cursor (idempotent: unknown names are a
    /// no-op).  The antidote to a dead consumer's pin blocking the
    /// compaction floor forever: once dropped, the pin no longer clamps
    /// [`MemStore::compact_before`] / the durable compactor, and a
    /// late-returning consumer of that name simply degrades to the
    /// full-table fallback on its next fetch.
    fn drop_cursor(&self, name: &str) -> Result<()>;

    /// Store-clock in nanoseconds (monotonic, starts near 0).
    fn now(&self) -> Result<u64>;

    /// Aggregate op counters.
    fn stats(&self) -> Result<StoreStats>;
}

/// One stored parameter layer: name, payload, last-write version.
struct ParamLayer {
    name: String,
    bytes: Vec<u8>,
    /// Params version that last wrote this layer.
    version: u64,
}

struct ParamSlot {
    version: u64,
    /// Named layer chunks in layout order (their concatenation is the
    /// wire blob [`WeightStore::fetch_params`] serves).  A whole-blob
    /// publish stores a single unnamed chunk.
    layers: Vec<ParamLayer>,
    /// Caller versions `< floor` cannot be served layer-precisely (the
    /// layout was (re)defined at `floor`): `fetch_params_since` falls
    /// back to the full layout for them.
    floor: u64,
}

impl ParamSlot {
    fn blob(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.layers.iter().map(|l| l.bytes.len()).sum());
        for l in &self.layers {
            out.extend_from_slice(&l.bytes);
        }
        out
    }
}

/// A saved consumer cursor: the pinned sequence plus the store-clock
/// stamp of its last save (the max-age expiry signal).
struct CursorPin {
    seq: u64,
    saved_at: u64,
}

/// One contiguous stripe of the weight table.
struct WeightShard {
    /// Global index of this shard's entry 0.
    base: usize,
    weights: Vec<f64>,
    stamps: Vec<u64>,
    param_versions: Vec<u64>,
    /// Write sequence of each entry's last write (0 = initial value only).
    write_seqs: Vec<u64>,
    /// Highest write sequence recorded in this shard — lets delta fetches
    /// skip untouched shards without scanning their entries.
    max_seq: u64,
}

/// Number of lock stripes the weight table is split into.  Contiguous
/// striping (not modulo) because workers push contiguous shard runs: a
/// push then touches at most ⌈run/chunk⌉ locks instead of all of them.
const WEIGHT_SHARDS: usize = 16;

/// In-process storage engine (also the backend behind the TCP server).
pub struct MemStore {
    params: Mutex<ParamSlot>,
    shards: Vec<RwLock<WeightShard>>,
    /// Entries per shard (the last shard may be shorter).
    chunk: usize,
    /// Total tracked examples.
    n: usize,
    /// Global write-sequence counter; claimed under a shard's write lock.
    next_seq: AtomicU64,
    /// Named consumer cursors ([`WeightStore::save_cursor`]): compaction
    /// pins + crash-resume state.  Also serializes compactions.
    cursors: Mutex<BTreeMap<String, CursorPin>>,
    /// Write sequences `< compact_floor` have been folded together by
    /// [`MemStore::compact_before`]; a fetch cursor below the floor can
    /// only be served the full table.
    compact_floor: AtomicU64,
    /// Added to the elapsed-time clock so a recovered durable store keeps
    /// `now()` (and thus stamps) monotonic across restarts.
    clock_offset: AtomicU64,
    start: Instant,
    param_pushes: AtomicU64,
    param_fetches: AtomicU64,
    weight_pushes: AtomicU64,
    weights_written: AtomicU64,
    snapshot_fetches: AtomicU64,
    grad_applies: AtomicU64,
    delta_fetches: AtomicU64,
    delta_entries: AtomicU64,
    params_delta_fetches: AtomicU64,
    params_delta_layers: AtomicU64,
}

impl MemStore {
    /// Create a store tracking `n` examples, all weights initialised to
    /// `init_weight` (the paper starts from uniform — every example must
    /// be samplable before the first worker sweep completes).
    pub fn new(n: usize, init_weight: f64) -> Self {
        let chunk = n.div_ceil(WEIGHT_SHARDS).max(1);
        let mut shards = Vec::new();
        let mut base = 0;
        while base < n || (n == 0 && shards.is_empty()) {
            let len = chunk.min(n - base);
            shards.push(RwLock::new(WeightShard {
                base,
                weights: vec![init_weight; len],
                stamps: vec![0; len],
                param_versions: vec![0; len],
                // The initial state is "write" 1, so a consumer that has
                // absorbed the fresh table holds cursor 1 — distinct from
                // cursor 0, which means "send me everything".
                write_seqs: vec![1; len],
                max_seq: 1,
            }));
            base += chunk;
        }
        MemStore {
            params: Mutex::new(ParamSlot {
                version: 0,
                layers: Vec::new(),
                floor: 0,
            }),
            shards,
            chunk,
            n,
            next_seq: AtomicU64::new(1),
            cursors: Mutex::new(BTreeMap::new()),
            compact_floor: AtomicU64::new(0),
            clock_offset: AtomicU64::new(0),
            // analyze: allow(wallclock): anchor for the store's monotonic ns clock
            start: Instant::now(),
            param_pushes: AtomicU64::new(0),
            param_fetches: AtomicU64::new(0),
            weight_pushes: AtomicU64::new(0),
            weights_written: AtomicU64::new(0),
            snapshot_fetches: AtomicU64::new(0),
            grad_applies: AtomicU64::new(0),
            delta_fetches: AtomicU64::new(0),
            delta_entries: AtomicU64::new(0),
            params_delta_fetches: AtomicU64::new(0),
            params_delta_layers: AtomicU64::new(0),
        }
    }

    pub fn n_examples(&self) -> usize {
        self.n
    }

    /// Current global write sequence (diagnostics/tests).
    pub fn write_seq(&self) -> u64 {
        self.next_seq.load(Ordering::Acquire)
    }

    /// Oldest saved consumer cursor — the compaction pin (`None` when no
    /// consumer ever saved one).
    pub fn oldest_cursor(&self) -> Option<u64> {
        self.cursors.lock().unwrap().values().map(|p| p.seq).min()
    }

    /// Params versions below this cannot be served layer-precisely
    /// (layout (re)definition point — see the module docs).
    pub fn params_floor(&self) -> u64 {
        self.params.lock().unwrap().floor
    }

    /// Write sequences below this value have been folded together by
    /// [`MemStore::compact_before`]; fetch cursors below it fall back to
    /// the full table.
    pub fn compact_floor(&self) -> u64 {
        self.compact_floor.load(Ordering::Acquire)
    }

    /// Truncate write-sequence history below
    /// `min(limit, oldest saved cursor, current write sequence)`: every
    /// entry older than that horizon is re-tagged *at* the horizon, so the
    /// distinct-sequence history a persistent backend must retain shrinks
    /// to the span live consumers can actually ask about (see
    /// [`WeightStore::save_cursor`] for the safety contract).  Returns the
    /// new floor; the floor never moves backwards.  The durable compactor
    /// calls this before every snapshot — it is what finally lets
    /// `write_seqs` history be truncated on disk as well as in memory.
    pub fn compact_before(&self, limit: u64) -> u64 {
        // Serialize compactions on the cursor lock; pins can be added or
        // advanced concurrently, but a pin present *before* the fold
        // started is honoured, which is all the contract promises.
        let cursors = self.cursors.lock().unwrap();
        let pin = cursors.values().map(|p| p.seq).min().unwrap_or(u64::MAX);
        let target = limit.min(pin).min(self.next_seq.load(Ordering::Acquire));
        let old = self.compact_floor.load(Ordering::Acquire);
        if target <= old {
            return old;
        }
        // Publish the floor FIRST: a reader whose cursor is below the new
        // floor immediately degrades to full fetches, so the per-entry
        // re-tagging below can never hide a write from it.
        self.compact_floor.store(target, Ordering::Release);
        for lock in &self.shards {
            let mut sh = lock.write().unwrap();
            for s in sh.write_seqs.iter_mut() {
                if *s < target {
                    *s = target;
                }
            }
            sh.max_seq = sh.max_seq.max(target);
        }
        target
    }

    // -- durable-backend plumbing (crate-internal) --------------------------

    /// Overwrite entries with explicit sequence/stamp/version values — the
    /// durable recovery path: replaying journal frames must reproduce the
    /// pre-crash table bit-exactly (write sequences and stamps included),
    /// never re-stamp it.
    pub(crate) fn restore_delta(&self, d: &WeightDelta) -> Result<()> {
        anyhow::ensure!(
            d.n as usize == self.n,
            "restore frame tracks {} entries, store holds {}",
            d.n,
            self.n
        );
        anyhow::ensure!(
            d.indices.len() == d.weights.len()
                && d.weights.len() == d.stamps.len()
                && d.stamps.len() == d.param_versions.len(),
            "restore frame columns disagree on length"
        );
        for &idx in &d.indices {
            anyhow::ensure!((idx as usize) < self.n, "restore index {idx} out of bounds");
        }
        for lock in &self.shards {
            let mut sh = lock.write().unwrap();
            let base = sh.base;
            let len = sh.weights.len();
            let mut touched = false;
            for (k, &idx) in d.indices.iter().enumerate() {
                let i = idx as usize;
                if i < base || i >= base + len {
                    continue;
                }
                let j = i - base;
                sh.weights[j] = d.weights[k];
                sh.stamps[j] = d.stamps[k];
                sh.param_versions[j] = d.param_versions[k];
                sh.write_seqs[j] = d.seq;
                touched = true;
            }
            if touched {
                sh.max_seq = sh.max_seq.max(d.seq);
            }
        }
        self.next_seq.fetch_max(d.seq, Ordering::AcqRel);
        Ok(())
    }

    /// Set the parameter slot from a whole blob directly (legacy journal
    /// record replay: last record wins, no monotonicity check).  The blob
    /// becomes a single unnamed layer and the floor rises to `version`.
    pub(crate) fn restore_params(&self, version: u64, bytes: Vec<u8>) {
        let mut slot = self.params.lock().unwrap();
        slot.version = version;
        slot.layers = vec![ParamLayer {
            name: String::new(),
            bytes,
            version,
        }];
        slot.floor = version;
    }

    /// Replay a journaled layer push exactly (no monotonicity check —
    /// journal order is push order).  Mirrors
    /// [`WeightStore::push_params_layers`] semantics.
    pub(crate) fn replay_params_layers(
        &self,
        version: u64,
        full: bool,
        layers: &[(String, Vec<u8>)],
    ) -> Result<()> {
        let mut slot = self.params.lock().unwrap();
        if full || slot.version == 0 {
            anyhow::ensure!(full, "journaled partial layer push before any layout");
            slot.layers = layers
                .iter()
                .map(|(n, b)| ParamLayer {
                    name: n.clone(),
                    bytes: b.clone(),
                    version,
                })
                .collect();
            slot.floor = version;
        } else {
            for (n, b) in layers {
                let l = slot
                    .layers
                    .iter_mut()
                    .find(|l| &l.name == n)
                    .with_context(|| format!("journaled push names unknown layer {n:?}"))?;
                l.bytes = b.clone();
                l.version = version;
            }
        }
        slot.version = version.max(slot.version);
        Ok(())
    }

    /// Append one layer during snapshot restore, preserving layout order
    /// and the per-layer version recorded at checkpoint time.
    pub(crate) fn snapshot_append_param_layer(&self, name: String, version: u64, bytes: Vec<u8>) {
        self.params.lock().unwrap().layers.push(ParamLayer {
            name,
            bytes,
            version,
        });
    }

    /// Set the params head version + floor (snapshot meta restore).
    pub(crate) fn restore_params_meta(&self, version: u64, floor: u64) {
        let mut slot = self.params.lock().unwrap();
        slot.version = version;
        slot.floor = floor;
    }

    pub(crate) fn restore_cursor(&self, name: String, seq: u64, saved_at: u64) {
        self.cursors
            .lock()
            .unwrap()
            .insert(name, CursorPin { seq, saved_at });
    }

    /// Save a cursor and report what was actually stored: the clamped
    /// sequence plus the store-clock stamp — the durable journal records
    /// both so replay is bit-exact.
    pub(crate) fn save_cursor_pin(&self, name: &str, seq: u64) -> Result<(u64, u64)> {
        anyhow::ensure!(!name.is_empty(), "cursor name must be non-empty");
        let clamped = seq.min(self.next_seq.load(Ordering::Acquire));
        let saved_at = self.now()?;
        self.cursors
            .lock()
            .unwrap()
            .insert(name.to_string(), CursorPin { seq: clamped, saved_at });
        Ok((clamped, saved_at))
    }

    /// Drop every pin whose last save predates `cutoff` (store-clock ns);
    /// returns the reaped `(name, seq)` pairs.  The durable compactor's
    /// max-age expiry — a dead consumer's pin stops blocking the floor,
    /// at the documented cost that the consumer, if it ever returns,
    /// degrades to the full-table fallback.
    pub(crate) fn expire_cursors(&self, cutoff: u64) -> Vec<(String, u64)> {
        let mut cursors = self.cursors.lock().unwrap();
        let doomed: Vec<String> = cursors
            .iter()
            .filter(|(_, p)| p.saved_at < cutoff)
            .map(|(n, _)| n.clone())
            .collect();
        doomed
            .into_iter()
            .map(|n| {
                let pin = cursors.remove(&n).unwrap();
                (n, pin.seq)
            })
            .collect()
    }

    pub(crate) fn restore_floor(&self, floor: u64) {
        self.compact_floor.fetch_max(floor, Ordering::AcqRel);
    }

    pub(crate) fn force_write_seq(&self, seq: u64) {
        self.next_seq.fetch_max(seq, Ordering::AcqRel);
    }

    /// Make [`WeightStore::now`] return at least `ns` from here on — a
    /// recovered store must keep stamps monotonic across the restart.
    pub(crate) fn advance_clock_to(&self, ns: u64) {
        self.clock_offset.fetch_max(ns, Ordering::AcqRel);
    }

    /// Point-in-time copy of the full table *including write sequences*
    /// (all shard read locks held, like `fetch_weights`) — the snapshot
    /// writer's input.
    pub(crate) fn dump_with_seqs(&self) -> (WeightSnapshot, Vec<u64>) {
        let guards: Vec<_> = self.shards.iter().map(|l| l.read().unwrap()).collect();
        let mut snap = WeightSnapshot {
            weights: Vec::with_capacity(self.n),
            stamps: Vec::with_capacity(self.n),
            param_versions: Vec::with_capacity(self.n),
        };
        let mut seqs = Vec::with_capacity(self.n);
        for sh in &guards {
            snap.weights.extend_from_slice(&sh.weights);
            snap.stamps.extend_from_slice(&sh.stamps);
            snap.param_versions.extend_from_slice(&sh.param_versions);
            seqs.extend_from_slice(&sh.write_seqs);
        }
        (snap, seqs)
    }

    /// Current parameter state `(version, floor, layer chunks in layout
    /// order)` — snapshot writer input.
    pub(crate) fn params_layers_dump(&self) -> (u64, u64, Vec<LayerChunk>) {
        let slot = self.params.lock().unwrap();
        let layers = slot
            .layers
            .iter()
            .map(|l| LayerChunk {
                name: l.name.clone(),
                version: l.version,
                bytes: l.bytes.clone(),
            })
            .collect();
        (slot.version, slot.floor, layers)
    }

    /// All saved consumer cursors `(name, seq, saved_at)` — snapshot
    /// writer input.
    pub(crate) fn cursors_vec(&self) -> Vec<(String, u64, u64)> {
        self.cursors
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.seq, v.saved_at))
            .collect()
    }

    /// Claim-and-write like [`WeightStore::push_weights`], returning the
    /// claimed `(write_seq, stamp)` (`None` for an empty run) — the
    /// durable journal needs both to record the exact entry state this
    /// push created.
    pub(crate) fn push_weights_seq(
        &self,
        start: usize,
        weights: &[f32],
        param_version: u64,
    ) -> Result<Option<(u64, u64)>> {
        anyhow::ensure!(
            start + weights.len() <= self.n,
            "weight range {}..{} out of bounds (n = {})",
            start,
            start + weights.len(),
            self.n
        );
        // Validate before taking any lock: a bad value must not leave a
        // half-applied run behind.
        for (i, &w) in weights.iter().enumerate() {
            anyhow::ensure!(w.is_finite() && w >= 0.0, "weight {w} invalid at {}", start + i);
        }
        let now = self.now()?;
        let mut claimed = None;
        if !weights.is_empty() {
            let end = start + weights.len();
            // Hold EVERY touched shard's write lock for the whole run
            // (ascending order, so writers can't deadlock each other or
            // the all-shards snapshot reader): a push is atomic — no
            // reader observes half of it — and one sequence value covers
            // it.  Claiming under the locks keeps the no-lost-updates
            // guarantee: a reader that loaded a cursor ≥ `seq` blocks on
            // these shards until the entries below are visible.
            let first = start / self.chunk;
            let last = (end - 1) / self.chunk;
            let mut guards: Vec<_> = (first..=last)
                .map(|s| self.shards[s].write().unwrap())
                .collect();
            let seq = self.next_seq.fetch_add(1, Ordering::AcqRel) + 1;
            for sh in guards.iter_mut() {
                let lo = start.max(sh.base);
                let hi = end.min(sh.base + sh.weights.len());
                for j in lo..hi {
                    let k = j - sh.base;
                    sh.weights[k] = weights[j - start] as f64;
                    sh.stamps[k] = now;
                    sh.param_versions[k] = param_version;
                    sh.write_seqs[k] = seq;
                }
                sh.max_seq = sh.max_seq.max(seq);
            }
            claimed = Some((seq, now));
        }
        self.weight_pushes.fetch_add(1, Ordering::Relaxed);
        self.weights_written
            .fetch_add(weights.len() as u64, Ordering::Relaxed);
        Ok(claimed)
    }
}

impl WeightStore for MemStore {
    fn push_params(&self, version: u64, bytes: Vec<u8>) -> Result<()> {
        let mut slot = self.params.lock().unwrap();
        anyhow::ensure!(
            version > slot.version,
            "parameter version must increase: {} -> {}",
            slot.version,
            version
        );
        slot.version = version;
        // A whole-blob publish has no layer structure: it replaces the
        // layout with one unnamed chunk and resets per-layer history.
        slot.layers = vec![ParamLayer {
            name: String::new(),
            bytes,
            version,
        }];
        slot.floor = version;
        self.param_pushes.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    fn push_params_layers(
        &self,
        version: u64,
        full: bool,
        layers: &[(String, Vec<u8>)],
    ) -> Result<()> {
        anyhow::ensure!(!layers.is_empty(), "layer push carries no layers");
        let mut slot = self.params.lock().unwrap();
        anyhow::ensure!(
            version > slot.version,
            "parameter version must increase: {} -> {}",
            slot.version,
            version
        );
        if full || slot.version == 0 {
            anyhow::ensure!(
                full,
                "first layer publish must be full (the layout is undefined)"
            );
            let mut seen = std::collections::BTreeSet::new();
            for (i, (n, _)) in layers.iter().enumerate() {
                anyhow::ensure!(!n.is_empty(), "layer {i} has an empty name");
                anyhow::ensure!(
                    seen.insert(n.as_str()),
                    "duplicate layer name {n:?} in full publish"
                );
            }
            slot.layers = layers
                .iter()
                .map(|(n, b)| ParamLayer {
                    name: n.clone(),
                    bytes: b.clone(),
                    version,
                })
                .collect();
            // Layout (re)definition: older per-layer history is gone.
            slot.floor = version;
        } else {
            // Validate every named layer before mutating any: a bad push
            // must not leave the layout half-updated.
            for (n, b) in layers {
                let l = slot.layers.iter().find(|l| &l.name == n).with_context(|| {
                    format!("push names unknown layer {n:?}; republish the full layout")
                })?;
                anyhow::ensure!(
                    l.bytes.len() == b.len(),
                    "layer {n:?} is {} bytes, push carries {}",
                    l.bytes.len(),
                    b.len()
                );
            }
            for (n, b) in layers {
                // Presence was validated above; a (can't-happen) miss is a
                // no-op rather than an event-loop abort.
                if let Some(l) = slot.layers.iter_mut().find(|l| &l.name == n) {
                    l.bytes = b.clone();
                    l.version = version;
                }
            }
        }
        slot.version = version;
        self.param_pushes.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    fn fetch_params(&self, than: u64) -> Result<Option<(u64, Vec<u8>)>> {
        let slot = self.params.lock().unwrap();
        self.param_fetches.fetch_add(1, Ordering::Relaxed);
        if slot.version > than {
            Ok(Some((slot.version, slot.blob())))
        } else {
            Ok(None)
        }
    }

    fn fetch_params_since(&self, than: u64) -> Result<Option<ParamsDelta>> {
        let slot = self.params.lock().unwrap();
        self.params_delta_fetches.fetch_add(1, Ordering::Relaxed);
        if slot.version == 0 || than == slot.version {
            return Ok(None);
        }
        // Version 0 (bootstrap), below the floor (layout redefined since),
        // or from the future (restarted store): only the full layout can
        // be served.  `than == 0 < floor` always, but spell it out.
        let full = than == 0 || than < slot.floor || than > slot.version;
        let layers: Vec<LayerChunk> = slot
            .layers
            .iter()
            .filter(|l| full || l.version > than)
            .map(|l| LayerChunk {
                name: l.name.clone(),
                version: l.version,
                bytes: l.bytes.clone(),
            })
            .collect();
        self.params_delta_layers
            .fetch_add(layers.len() as u64, Ordering::Relaxed);
        Ok(Some(ParamsDelta {
            version: slot.version,
            full,
            layers,
        }))
    }

    fn params_version(&self) -> Result<u64> {
        Ok(self.params.lock().unwrap().version)
    }

    fn push_weights(&self, start: usize, weights: &[f32], param_version: u64) -> Result<()> {
        self.push_weights_seq(start, weights, param_version).map(|_| ())
    }

    fn fetch_weights(&self) -> Result<WeightSnapshot> {
        self.snapshot_fetches.fetch_add(1, Ordering::Relaxed);
        // Acquire every shard read lock before copying: snapshots stay
        // point-in-time atomic (pushes hold all their touched shard locks,
        // so none can be observed half-applied).  Deadlock-free because
        // every multi-lock acquirer — this reader and push_weights — takes
        // shard locks in ascending index order.  Delta fetches deliberately
        // don't pay this: their cursor contract already tolerates per-shard
        // scan races.
        let guards: Vec<_> = self.shards.iter().map(|l| l.read().unwrap()).collect();
        let mut snap = WeightSnapshot {
            weights: Vec::with_capacity(self.n),
            stamps: Vec::with_capacity(self.n),
            param_versions: Vec::with_capacity(self.n),
        };
        for sh in &guards {
            snap.weights.extend_from_slice(&sh.weights);
            snap.stamps.extend_from_slice(&sh.stamps);
            snap.param_versions.extend_from_slice(&sh.param_versions);
        }
        Ok(snap)
    }

    fn fetch_weights_since(&self, seq: u64) -> Result<WeightDelta> {
        // Cursor FIRST, scan second: writes sequenced at or below the
        // cursor are guaranteed visible to the scan (see module docs);
        // writes racing past it are at worst re-delivered next time.  A
        // caller cursor below the compaction floor can no longer be served
        // precisely (history below the floor has been folded together) and
        // falls back to the full table.
        let cursor = self.next_seq.load(Ordering::Acquire);
        let floor = self.compact_floor.load(Ordering::Acquire);
        let full = seq == 0 || seq > cursor || seq < floor;
        let mut delta = WeightDelta {
            seq: cursor,
            n: self.n as u64,
            full,
            ..WeightDelta::default()
        };
        for lock in &self.shards {
            let sh = lock.read().unwrap();
            if !full && sh.max_seq <= seq {
                continue;
            }
            for k in 0..sh.weights.len() {
                if full || sh.write_seqs[k] > seq {
                    delta.indices.push((sh.base + k) as u64);
                    delta.weights.push(sh.weights[k]);
                    delta.stamps.push(sh.stamps[k]);
                    delta.param_versions.push(sh.param_versions[k]);
                }
            }
        }
        self.delta_fetches.fetch_add(1, Ordering::Relaxed);
        self.delta_entries
            .fetch_add(delta.len() as u64, Ordering::Relaxed);
        Ok(delta)
    }

    fn apply_grad(&self, scale: f32, grad: &[f32]) -> Result<u64> {
        anyhow::ensure!(scale.is_finite(), "scale {scale} invalid");
        let mut slot = self.params.lock().unwrap();
        anyhow::ensure!(slot.version > 0, "no parameters published yet");
        let total: usize = slot.layers.iter().map(|l| l.bytes.len()).sum();
        anyhow::ensure!(
            total == grad.len() * 4,
            "gradient has {} values, parameter blob holds {}",
            grad.len(),
            total / 4
        );
        // Validate alignment before mutating anything: a bad layer must
        // not leave the blob half-updated.
        for l in &slot.layers {
            anyhow::ensure!(
                l.bytes.len() % 4 == 0,
                "layer {:?} is not f32-aligned ({} bytes)",
                l.name,
                l.bytes.len()
            );
        }
        // The gradient spans the whole flat parameter vector, so every
        // layer is touched and stamped with the new version.
        let new_version = slot.version + 1;
        let mut off = 0usize;
        for l in slot.layers.iter_mut() {
            for chunk in l.bytes.chunks_exact_mut(4) {
                if let [a, b, c, d] = *chunk {
                    let v = f32::from_le_bytes([a, b, c, d]) - scale * grad[off];
                    chunk.copy_from_slice(&v.to_le_bytes());
                }
                off += 1;
            }
            l.version = new_version;
        }
        slot.version = new_version;
        self.grad_applies.fetch_add(1, Ordering::Relaxed);
        Ok(slot.version)
    }

    fn save_cursor(&self, name: &str, seq: u64) -> Result<()> {
        self.save_cursor_pin(name, seq).map(|_| ())
    }

    fn load_cursor(&self, name: &str) -> Result<Option<u64>> {
        Ok(self.cursors.lock().unwrap().get(name).map(|p| p.seq))
    }

    fn drop_cursor(&self, name: &str) -> Result<()> {
        self.cursors.lock().unwrap().remove(name);
        Ok(())
    }

    fn now(&self) -> Result<u64> {
        Ok(self.clock_offset.load(Ordering::Acquire) + self.start.elapsed().as_nanos() as u64)
    }

    fn stats(&self) -> Result<StoreStats> {
        Ok(StoreStats {
            param_pushes: self.param_pushes.load(Ordering::Relaxed),
            param_fetches: self.param_fetches.load(Ordering::Relaxed),
            weight_pushes: self.weight_pushes.load(Ordering::Relaxed),
            weights_written: self.weights_written.load(Ordering::Relaxed),
            snapshot_fetches: self.snapshot_fetches.load(Ordering::Relaxed),
            grad_applies: self.grad_applies.load(Ordering::Relaxed),
            delta_fetches: self.delta_fetches.load(Ordering::Relaxed),
            delta_entries: self.delta_entries.load(Ordering::Relaxed),
            params_delta_fetches: self.params_delta_fetches.load(Ordering::Relaxed),
            params_delta_layers: self.params_delta_layers.load(Ordering::Relaxed),
            push_calls_saved: 0,
            protocol_errors: 0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_roundtrip_and_versioning() {
        let s = MemStore::new(4, 1.0);
        assert_eq!(s.params_version().unwrap(), 0);
        assert!(s.fetch_params(0).unwrap().is_none());
        s.push_params(1, vec![1, 2, 3]).unwrap();
        let (v, b) = s.fetch_params(0).unwrap().unwrap();
        assert_eq!((v, b), (1, vec![1, 2, 3]));
        assert!(s.fetch_params(1).unwrap().is_none()); // up to date
        assert!(s.push_params(1, vec![]).is_err()); // must increase
        s.push_params(5, vec![9]).unwrap();
        assert_eq!(s.params_version().unwrap(), 5);
    }

    #[test]
    fn weights_init_and_push() {
        let s = MemStore::new(5, 2.5);
        let snap = s.fetch_weights().unwrap();
        assert_eq!(snap.weights, vec![2.5; 5]);
        s.push_weights(1, &[7.0, 8.0], 3).unwrap();
        let snap = s.fetch_weights().unwrap();
        assert_eq!(snap.weights, vec![2.5, 7.0, 8.0, 2.5, 2.5]);
        assert_eq!(snap.param_versions, vec![0, 3, 3, 0, 0]);
        assert!(snap.stamps[1] > 0);
    }

    #[test]
    fn rejects_out_of_bounds_and_bad_values() {
        let s = MemStore::new(3, 1.0);
        assert!(s.push_weights(2, &[1.0, 1.0], 1).is_err());
        assert!(s.push_weights(0, &[f32::NAN], 1).is_err());
        assert!(s.push_weights(0, &[-1.0], 1).is_err());
    }

    #[test]
    fn bad_value_leaves_no_partial_write() {
        let s = MemStore::new(3, 1.0);
        assert!(s.push_weights(0, &[5.0, f32::NAN, 5.0], 1).is_err());
        assert_eq!(s.fetch_weights().unwrap().weights, vec![1.0; 3]);
        assert_eq!(s.write_seq(), 1); // only the init "write"
    }

    #[test]
    fn stats_count_ops() {
        let s = MemStore::new(3, 1.0);
        s.push_params(1, vec![0]).unwrap();
        s.fetch_params(0).unwrap();
        s.push_weights(0, &[1.0, 2.0], 1).unwrap();
        s.fetch_weights().unwrap();
        s.fetch_weights_since(0).unwrap();
        let st = s.stats().unwrap();
        assert_eq!(st.param_pushes, 1);
        assert_eq!(st.param_fetches, 1);
        assert_eq!(st.weight_pushes, 1);
        assert_eq!(st.weights_written, 2);
        assert_eq!(st.snapshot_fetches, 1);
        assert_eq!(st.delta_fetches, 1);
        assert_eq!(st.delta_entries, 3); // seq 0 => full table
    }

    #[test]
    fn apply_grad_is_elementwise_sgd() {
        let s = MemStore::new(2, 1.0);
        // params = [1.0, 2.0, -3.0]
        let mut blob = Vec::new();
        for v in [1.0f32, 2.0, -3.0] {
            blob.extend(v.to_le_bytes());
        }
        s.push_params(1, blob).unwrap();
        let v = s.apply_grad(0.5, &[2.0, -2.0, 4.0]).unwrap();
        assert_eq!(v, 2);
        let (ver, bytes) = s.fetch_params(0).unwrap().unwrap();
        assert_eq!(ver, 2);
        let got: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        assert_eq!(got, vec![0.0, 3.0, -5.0]);
        assert_eq!(s.stats().unwrap().grad_applies, 1);
    }

    #[test]
    fn apply_grad_validates() {
        let s = MemStore::new(2, 1.0);
        assert!(s.apply_grad(0.1, &[1.0]).is_err()); // no params yet
        s.push_params(1, vec![0u8; 8]).unwrap();
        assert!(s.apply_grad(0.1, &[1.0]).is_err()); // size mismatch
        assert!(s.apply_grad(f32::NAN, &[1.0, 1.0]).is_err());
    }

    #[test]
    fn clock_is_monotonic() {
        let s = MemStore::new(1, 0.0);
        let a = s.now().unwrap();
        let b = s.now().unwrap();
        assert!(b >= a);
    }

    #[test]
    fn concurrent_pushers_do_not_lose_writes() {
        use std::sync::Arc;
        let s = Arc::new(MemStore::new(1000, 0.0));
        let mut handles = Vec::new();
        for t in 0..4 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for i in 0..250 {
                    let idx = t * 250 + i;
                    s.push_weights(idx, &[(idx + 1) as f32], 1).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = s.fetch_weights().unwrap();
        for (i, &w) in snap.weights.iter().enumerate() {
            assert_eq!(w, (i + 1) as f64);
        }
    }

    // -- delta semantics ----------------------------------------------------

    #[test]
    fn delta_seq_zero_is_full_table() {
        let s = MemStore::new(7, 2.0);
        let d = s.fetch_weights_since(0).unwrap();
        assert!(d.full);
        assert_eq!(d.n, 7);
        assert_eq!(d.len(), 7);
        assert_eq!(d.seq, 1); // the init state is write 1
        assert_eq!(d.indices, (0..7u64).collect::<Vec<_>>());
        assert_eq!(d.to_snapshot().unwrap(), s.fetch_weights().unwrap());
    }

    #[test]
    fn delta_returns_only_changes_since_cursor() {
        let s = MemStore::new(100, 1.0);
        let cursor = s.fetch_weights_since(0).unwrap().seq;
        assert_eq!(cursor, 1);
        s.push_weights(10, &[3.0, 4.0], 5).unwrap();
        s.push_weights(90, &[9.0], 6).unwrap();
        let d = s.fetch_weights_since(cursor).unwrap();
        assert!(!d.full);
        assert_eq!(d.indices, vec![10, 11, 90]);
        assert_eq!(d.weights, vec![3.0, 4.0, 9.0]);
        assert_eq!(d.param_versions, vec![5, 5, 6]);
        // Idle store: the next delta is empty and the cursor is stable.
        let d2 = s.fetch_weights_since(d.seq).unwrap();
        assert!(d2.is_empty());
        assert_eq!(d2.seq, d.seq);
    }

    #[test]
    fn delta_rewrite_of_same_entry_carries_latest_value() {
        let s = MemStore::new(8, 0.0);
        let cursor = s.fetch_weights_since(0).unwrap().seq;
        s.push_weights(3, &[1.0], 1).unwrap();
        s.push_weights(3, &[2.0], 2).unwrap();
        let d = s.fetch_weights_since(cursor).unwrap();
        assert_eq!(d.indices, vec![3]);
        assert_eq!(d.weights, vec![2.0]);
        assert_eq!(d.param_versions, vec![2]);
    }

    #[test]
    fn delta_future_cursor_falls_back_to_full() {
        let s = MemStore::new(4, 1.0);
        s.push_weights(0, &[5.0], 1).unwrap();
        let d = s.fetch_weights_since(u64::MAX).unwrap();
        assert!(d.full);
        assert_eq!(d.len(), 4);
    }

    #[test]
    fn delta_apply_to_tracks_snapshot() {
        let s = MemStore::new(50, 1.5);
        let mut mirror = WeightSnapshot::default();
        let d = s.fetch_weights_since(0).unwrap();
        d.apply_to(&mut mirror).unwrap();
        let mut cursor = d.seq;
        for round in 0..10u64 {
            let start = (round as usize * 7) % 40;
            let vals: Vec<f32> = (0..5).map(|i| (round * 10 + i) as f32).collect();
            s.push_weights(start, &vals, round + 1).unwrap();
            let d = s.fetch_weights_since(cursor).unwrap();
            d.apply_to(&mut mirror).unwrap();
            cursor = d.seq;
        }
        assert_eq!(mirror, s.fetch_weights().unwrap());
    }

    #[test]
    fn delta_spanning_multiple_shards_is_complete() {
        // 100 entries over 16 shards => chunk 7: a 40-long run crosses
        // several shard boundaries and must come back whole.
        let s = MemStore::new(100, 0.0);
        let cursor = s.fetch_weights_since(0).unwrap().seq;
        let vals: Vec<f32> = (0..40).map(|i| i as f32 + 1.0).collect();
        s.push_weights(30, &vals, 1).unwrap();
        let d = s.fetch_weights_since(cursor).unwrap();
        assert_eq!(d.indices, (30..70u64).collect::<Vec<_>>());
        assert_eq!(d.weights, (0..40).map(|i| i as f64 + 1.0).collect::<Vec<_>>());
    }

    #[test]
    fn delta_reader_never_misses_concurrent_writes() {
        use std::sync::Arc;
        let s = Arc::new(MemStore::new(600, 0.0));
        let mut mirror = WeightSnapshot::default();
        let d = s.fetch_weights_since(0).unwrap();
        d.apply_to(&mut mirror).unwrap();
        let mut cursor = d.seq;
        let mut handles = Vec::new();
        for t in 0..3u64 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                // Overlapping ranges on purpose: last write wins, and the
                // reader must converge on whatever that is.
                for i in 0..200usize {
                    let idx = (t as usize * 150 + i) % 600;
                    s.push_weights(idx, &[(t * 1000 + i as u64) as f32], t + 1).unwrap();
                }
            }));
        }
        // Race the reader against the writers.
        for _ in 0..50 {
            let d = s.fetch_weights_since(cursor).unwrap();
            d.apply_to(&mut mirror).unwrap();
            cursor = d.seq;
        }
        for h in handles {
            h.join().unwrap();
        }
        // Drain whatever remains and compare against the ground truth.
        let d = s.fetch_weights_since(cursor).unwrap();
        d.apply_to(&mut mirror).unwrap();
        assert_eq!(mirror, s.fetch_weights().unwrap());
    }

    #[test]
    fn malformed_delta_leaves_snapshot_untouched() {
        // apply_to must validate everything before mutating: an
        // out-of-bounds index errors with the snapshot byte-identical.
        let s = MemStore::new(4, 1.0);
        s.push_weights(1, &[3.0], 2).unwrap();
        let mut snap = s.fetch_weights().unwrap();
        let before = snap.clone();
        let bad = WeightDelta {
            seq: 9,
            n: 4,
            full: false,
            indices: vec![0, 7], // 7 is out of bounds
            weights: vec![5.0, 6.0],
            stamps: vec![1, 1],
            param_versions: vec![1, 1],
        };
        assert!(bad.apply_to(&mut snap).is_err());
        assert_eq!(snap, before);
        // Same for a full delta: no clear/resize before validation.
        let mut bad_full = bad.clone();
        bad_full.full = true;
        bad_full.indices = vec![0, 9];
        bad_full.weights = vec![5.0, 6.0];
        // full requires indices.len() == n; make lengths match n = 2.
        bad_full.n = 2;
        assert!(bad_full.apply_to(&mut snap).is_err());
        assert_eq!(snap, before);
    }

    // -- cursors + compaction ----------------------------------------------

    #[test]
    fn cursors_save_load_and_clamp() {
        let s = MemStore::new(4, 1.0);
        assert_eq!(s.load_cursor("master").unwrap(), None);
        s.save_cursor("master", 1).unwrap();
        assert_eq!(s.load_cursor("master").unwrap(), Some(1));
        // A cursor from the future clamps to the current write sequence.
        s.save_cursor("master", u64::MAX).unwrap();
        assert_eq!(s.load_cursor("master").unwrap(), Some(s.write_seq()));
        assert!(s.save_cursor("", 0).is_err());
        assert_eq!(s.oldest_cursor(), Some(s.write_seq()));
    }

    #[test]
    fn compact_before_respects_the_oldest_pin() {
        let s = MemStore::new(10, 1.0);
        for i in 0..6 {
            s.push_weights(i, &[i as f32 + 2.0], 1).unwrap();
        }
        let head = s.write_seq(); // 7: init + 6 pushes
        s.save_cursor("slow", 3).unwrap();
        s.save_cursor("fast", head).unwrap();
        // The fold clamps at the slowest consumer, not the requested limit.
        assert_eq!(s.compact_before(u64::MAX), 3);
        assert_eq!(s.compact_floor(), 3);
        // A consumer at the pin keeps incremental service and misses
        // nothing: entries 3.. (seqs 4..) are still distinguishable.
        let d = s.fetch_weights_since(3).unwrap();
        assert!(!d.full);
        assert_eq!(d.indices, vec![2, 3, 4, 5]);
        // A cursor below the floor degrades to the full-table fallback.
        let d = s.fetch_weights_since(2).unwrap();
        assert!(d.full);
        assert_eq!(d.len(), 10);
        // The floor never moves backwards.
        assert_eq!(s.compact_before(1), 3);
    }

    #[test]
    fn compaction_folds_history_but_loses_no_write() {
        let s = MemStore::new(20, 0.5);
        let d0 = s.fetch_weights_since(0).unwrap();
        let mut mirror = d0.to_snapshot().unwrap();
        let mut cursor = d0.seq;
        for round in 0..12u64 {
            s.push_weights((round as usize * 3) % 18, &[round as f32 + 1.0, 9.0], round + 1)
                .unwrap();
            if round == 5 {
                // Mid-stream fold up to our own saved cursor.
                s.save_cursor("me", cursor).unwrap();
                s.compact_before(u64::MAX);
            }
        }
        let d = s.fetch_weights_since(cursor).unwrap();
        d.apply_to(&mut mirror).unwrap();
        assert_eq!(mirror, s.fetch_weights().unwrap());
    }

    #[test]
    fn compact_with_no_pins_folds_everything() {
        let s = MemStore::new(4, 1.0);
        s.push_weights(0, &[3.0], 1).unwrap();
        let head = s.write_seq();
        assert_eq!(s.compact_before(u64::MAX), head);
        // Unpinned consumers fall back to full...
        assert!(s.fetch_weights_since(1).unwrap().full);
        // ...but a consumer exactly at the head stays incremental.
        let d = s.fetch_weights_since(head).unwrap();
        assert!(!d.full);
        assert!(d.is_empty());
    }

    #[test]
    fn restore_delta_reproduces_exact_entry_state() {
        let s = MemStore::new(10, 1.0);
        let d = WeightDelta {
            seq: 9,
            n: 10,
            full: false,
            indices: vec![2, 7],
            weights: vec![5.0, 6.0],
            stamps: vec![111, 222],
            param_versions: vec![3, 4],
        };
        s.restore_delta(&d).unwrap();
        let snap = s.fetch_weights().unwrap();
        assert_eq!(snap.weights[2], 5.0);
        assert_eq!(snap.stamps[7], 222);
        assert_eq!(snap.param_versions[2], 3);
        assert_eq!(s.write_seq(), 9);
        // The restored sequence is visible to delta fetches.
        let got = s.fetch_weights_since(8).unwrap();
        assert_eq!(got.indices, vec![2, 7]);
        // Bad frames are rejected wholesale.
        let bad = WeightDelta { n: 11, ..d.clone() };
        assert!(s.restore_delta(&bad).is_err());
    }

    #[test]
    fn advance_clock_keeps_now_monotonic() {
        let s = MemStore::new(1, 0.0);
        s.advance_clock_to(1_000_000_000);
        assert!(s.now().unwrap() >= 1_000_000_000);
    }

    // -- layer-wise params ---------------------------------------------------

    fn chunk(name: &str, bytes: &[u8]) -> (String, Vec<u8>) {
        (name.to_string(), bytes.to_vec())
    }

    #[test]
    fn layer_push_and_delta_fetch_ship_only_dirty_layers() {
        let s = MemStore::new(2, 1.0);
        assert!(s.fetch_params_since(0).unwrap().is_none()); // nothing yet
        s.push_params_layers(1, true, &[chunk("a", &[1, 1, 1, 1]), chunk("b", &[2, 2, 2, 2])])
            .unwrap();
        // Bootstrap (cursor 0): full layout in order.
        let d = s.fetch_params_since(0).unwrap().unwrap();
        assert!(d.full);
        assert_eq!(d.version, 1);
        assert_eq!(d.len(), 2);
        assert_eq!(d.layers[0].name, "a");
        assert_eq!(d.layers[1].name, "b");
        assert_eq!(d.to_blob().unwrap(), vec![1, 1, 1, 1, 2, 2, 2, 2]);
        // Partial update: only layer b ships to a caller at version 1.
        s.push_params_layers(2, false, &[chunk("b", &[9, 9, 9, 9])]).unwrap();
        let d = s.fetch_params_since(1).unwrap().unwrap();
        assert!(!d.full);
        assert_eq!(d.version, 2);
        assert_eq!(d.len(), 1);
        assert_eq!(d.layers[0].name, "b");
        assert_eq!(d.layers[0].version, 2);
        assert_eq!(d.layers[0].bytes, vec![9, 9, 9, 9]);
        // Up to date: None.
        assert!(s.fetch_params_since(2).unwrap().is_none());
        // The blob view concatenates the updated layout.
        let (v, blob) = s.fetch_params(0).unwrap().unwrap();
        assert_eq!((v, blob), (2, vec![1, 1, 1, 1, 9, 9, 9, 9]));
        assert_eq!(s.stats().unwrap().params_delta_fetches, 4);
    }

    #[test]
    fn params_delta_fallbacks_below_floor_and_from_the_future() {
        let s = MemStore::new(2, 1.0);
        s.push_params_layers(1, true, &[chunk("a", &[1]), chunk("b", &[2])]).unwrap();
        s.push_params_layers(2, false, &[chunk("a", &[3])]).unwrap();
        // Full-layout republish raises the floor: version-1 history is gone.
        s.push_params_layers(5, true, &[chunk("a", &[4]), chunk("b", &[5])]).unwrap();
        assert_eq!(s.params_floor(), 5);
        let d = s.fetch_params_since(2).unwrap().unwrap();
        assert!(d.full, "cursor below the params floor must fall back to full");
        assert_eq!(d.len(), 2);
        // A future cursor (restarted store) also degrades to full.
        let d = s.fetch_params_since(99).unwrap().unwrap();
        assert!(d.full);
        assert_eq!(d.version, 5);
    }

    #[test]
    fn layer_push_validates_layout_and_sizes() {
        let s = MemStore::new(2, 1.0);
        // First publish must be full.
        assert!(s.push_params_layers(1, false, &[chunk("a", &[1])]).is_err());
        // Full publish rejects empty and duplicate names.
        assert!(s.push_params_layers(1, true, &[chunk("", &[1])]).is_err());
        assert!(s
            .push_params_layers(1, true, &[chunk("a", &[1]), chunk("a", &[2])])
            .is_err());
        assert!(s.push_params_layers(1, true, &[]).is_err());
        s.push_params_layers(1, true, &[chunk("a", &[1, 2])]).unwrap();
        // Partial pushes must name known layers with matching sizes and
        // increasing versions.
        assert!(s.push_params_layers(2, false, &[chunk("nope", &[1, 2])]).is_err());
        assert!(s.push_params_layers(2, false, &[chunk("a", &[1])]).is_err());
        assert!(s.push_params_layers(1, false, &[chunk("a", &[3, 4])]).is_err());
        s.push_params_layers(2, false, &[chunk("a", &[3, 4])]).unwrap();
        assert_eq!(s.params_version().unwrap(), 2);
    }

    #[test]
    fn apply_grad_marks_every_layer_dirty() {
        let s = MemStore::new(2, 1.0);
        let zeros = vec![0u8; 4];
        s.push_params_layers(1, true, &[chunk("a", &zeros), chunk("b", &zeros)]).unwrap();
        let v = s.apply_grad(0.5, &[2.0, -2.0]).unwrap();
        assert_eq!(v, 2);
        let d = s.fetch_params_since(1).unwrap().unwrap();
        assert!(!d.full);
        assert_eq!(d.len(), 2, "a grad touches the whole layout");
        let (_, blob) = s.fetch_params(0).unwrap().unwrap();
        let got: Vec<f32> = blob
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        assert_eq!(got, vec![-1.0, 1.0]);
    }

    #[test]
    fn whole_blob_push_resets_the_layout_and_floor() {
        let s = MemStore::new(2, 1.0);
        s.push_params_layers(1, true, &[chunk("a", &[1]), chunk("b", &[2])]).unwrap();
        s.push_params(4, vec![7, 8]).unwrap();
        assert_eq!(s.params_floor(), 4);
        let d = s.fetch_params_since(1).unwrap().unwrap();
        assert!(d.full, "layer history does not survive a blob publish");
        assert_eq!(d.len(), 1);
        assert_eq!(d.layers[0].name, "");
        assert_eq!(d.to_blob().unwrap(), vec![7, 8]);
        // And a full layer publish on top re-layers the slot.
        s.push_params_layers(5, true, &[chunk("x", &[9])]).unwrap();
        assert_eq!(s.fetch_params(0).unwrap().unwrap().1, vec![9]);
    }

    #[test]
    fn drop_cursor_unblocks_the_compaction_floor() {
        let s = MemStore::new(8, 1.0);
        for i in 0..6 {
            s.push_weights(i, &[i as f32 + 2.0], 1).unwrap();
        }
        let head = s.write_seq();
        s.save_cursor("dead", 2).unwrap();
        s.save_cursor("live", head).unwrap();
        assert_eq!(s.compact_before(u64::MAX), 2, "dead pin clamps the fold");
        s.drop_cursor("dead").unwrap();
        assert_eq!(s.load_cursor("dead").unwrap(), None);
        // Dropping is idempotent and unblocks the floor.
        s.drop_cursor("dead").unwrap();
        assert_eq!(s.compact_before(u64::MAX), head);
        assert_eq!(s.oldest_cursor(), Some(head));
    }

    #[test]
    fn expire_cursors_reaps_only_stale_pins() {
        let s = MemStore::new(4, 1.0);
        s.push_weights(0, &[2.0], 1).unwrap();
        s.save_cursor("old", 1).unwrap();
        let cutoff = s.now().unwrap() + 1; // strictly after the save
        // "fresh" is saved at a clock reading at/after the cutoff.
        s.advance_clock_to(cutoff + 1);
        s.save_cursor("fresh", s.write_seq()).unwrap();
        let reaped = s.expire_cursors(cutoff);
        assert_eq!(reaped.len(), 1);
        assert_eq!(reaped[0].0, "old");
        assert_eq!(s.load_cursor("old").unwrap(), None);
        assert!(s.load_cursor("fresh").unwrap().is_some());
    }

    #[test]
    fn empty_store_delta_is_empty_full() {
        let s = MemStore::new(0, 1.0);
        let d = s.fetch_weights_since(0).unwrap();
        assert!(d.full);
        assert_eq!(d.n, 0);
        assert!(d.is_empty());
        assert!(s.fetch_weights().unwrap().is_empty());
    }
}
