//! Metrics: per-run time series, multi-seed aggregation (median/quartiles,
//! the statistics the paper plots over its 50 runs), and CSV/JSON export
//! consumed by the experiment drivers.
//!
//! analyze: allow-module(wallclock): samples are stamped with elapsed wall
//! time for the paper's time-axis plots; step-indexed data stays exact

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One sample of a named metric.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sample {
    /// Master step index at which the sample was taken.
    pub step: u64,
    /// Wall-clock seconds since run start.
    pub time_s: f64,
    pub value: f64,
}

/// All metrics of a single run.
#[derive(Debug, Clone, Default)]
pub struct RunRecorder {
    series: BTreeMap<String, Vec<Sample>>,
    start: Option<std::time::Instant>,
}

impl RunRecorder {
    pub fn new() -> Self {
        RunRecorder {
            series: BTreeMap::new(),
            start: Some(std::time::Instant::now()),
        }
    }

    pub fn record(&mut self, name: &str, step: u64, value: f64) {
        let time_s = self.start.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
        self.record_at(name, step, time_s, value);
    }

    pub fn record_at(&mut self, name: &str, step: u64, time_s: f64, value: f64) {
        self.series
            .entry(name.to_string())
            .or_default()
            .push(Sample { step, time_s, value });
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.series.keys().map(String::as_str)
    }

    pub fn get(&self, name: &str) -> &[Sample] {
        self.series.get(name).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Mean of the last `frac` (0..1] of samples — the paper's Table 1
    /// statistic ("average over the final 10% of iterations").
    pub fn tail_mean(&self, name: &str, frac: f64) -> Option<f64> {
        let xs = self.get(name);
        if xs.is_empty() {
            return None;
        }
        let keep = ((xs.len() as f64 * frac).ceil() as usize).clamp(1, xs.len());
        let tail = &xs[xs.len() - keep..];
        Some(tail.iter().map(|s| s.value).sum::<f64>() / tail.len() as f64)
    }

    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        for (name, samples) in &self.series {
            let arr = samples
                .iter()
                .map(|s| {
                    Json::Arr(vec![
                        Json::Num(s.step as f64),
                        Json::Num(s.time_s),
                        Json::Num(s.value),
                    ])
                })
                .collect();
            obj.insert(name.clone(), Json::Arr(arr));
        }
        Json::Obj(obj)
    }
}

/// Quartile summary of one metric across runs, per step.
#[derive(Debug, Clone)]
pub struct QuartileSeries {
    pub steps: Vec<u64>,
    pub q1: Vec<f64>,
    pub median: Vec<f64>,
    pub q3: Vec<f64>,
}

/// Median (and quartiles) across runs at each common step — the paper's
/// "thicker line plus a tube containing half the trajectories" (Fig. 2).
/// Steps present in only some runs are dropped (runs are normally
/// recorded on identical schedules).
pub fn quartiles_across_runs(runs: &[&RunRecorder], name: &str) -> QuartileSeries {
    let mut by_step: BTreeMap<u64, Vec<f64>> = BTreeMap::new();
    for run in runs {
        for s in run.get(name) {
            by_step.entry(s.step).or_default().push(s.value);
        }
    }
    let n_runs = runs.len();
    let mut out = QuartileSeries {
        steps: Vec::new(),
        q1: Vec::new(),
        median: Vec::new(),
        q3: Vec::new(),
    };
    for (step, mut vals) in by_step {
        if vals.len() != n_runs {
            continue;
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        out.steps.push(step);
        out.q1.push(quantile_sorted(&vals, 0.25));
        out.median.push(quantile_sorted(&vals, 0.5));
        out.q3.push(quantile_sorted(&vals, 0.75));
    }
    out
}

/// Linear-interpolated quantile of an ascending-sorted slice.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Write `series` as CSV: `step,q1,median,q3`.
pub fn write_quartile_csv(path: &Path, series: &QuartileSeries) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    writeln!(f, "step,q1,median,q3")?;
    for i in 0..series.steps.len() {
        writeln!(
            f,
            "{},{},{},{}",
            series.steps[i], series.q1[i], series.median[i], series.q3[i]
        )?;
    }
    Ok(())
}

/// Write several same-schedule quartile series side by side:
/// `step,<name1>_median,<name1>_q1,... ` — the "one CSV per figure" format.
pub fn write_figure_csv(path: &Path, named: &[(&str, &QuartileSeries)]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    anyhow::ensure!(!named.is_empty(), "no series");
    let steps = &named[0].1.steps;
    for (name, s) in named {
        anyhow::ensure!(
            &s.steps == steps,
            "series {name} has a different step schedule"
        );
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut header = String::from("step");
    for (name, _) in named {
        header.push_str(&format!(",{name}_q1,{name}_median,{name}_q3"));
    }
    writeln!(f, "{header}")?;
    for i in 0..steps.len() {
        let mut row = format!("{}", steps[i]);
        for (_, s) in named {
            row.push_str(&format!(",{},{},{}", s.q1[i], s.median[i], s.q3[i]));
        }
        writeln!(f, "{row}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_read_back() {
        let mut r = RunRecorder::new();
        r.record_at("loss", 0, 0.0, 2.0);
        r.record_at("loss", 1, 0.1, 1.0);
        r.record_at("acc", 0, 0.0, 0.5);
        assert_eq!(r.get("loss").len(), 2);
        assert_eq!(r.get("missing").len(), 0);
        assert_eq!(r.names().count(), 2);
    }

    #[test]
    fn tail_mean_last_fraction() {
        let mut r = RunRecorder::new();
        for i in 0..10 {
            r.record_at("x", i, 0.0, i as f64);
        }
        // last 10% of 10 samples = just the last one
        assert_eq!(r.tail_mean("x", 0.1), Some(9.0));
        // last 50% = mean of 5..9
        assert_eq!(r.tail_mean("x", 0.5), Some(7.0));
        assert_eq!(r.tail_mean("nope", 0.1), None);
    }

    #[test]
    fn quantiles_interpolate() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile_sorted(&xs, 0.0), 1.0);
        assert_eq!(quantile_sorted(&xs, 1.0), 4.0);
        assert_eq!(quantile_sorted(&xs, 0.5), 2.5);
    }

    #[test]
    fn quartiles_across_three_runs() {
        let mut runs = Vec::new();
        for v in [1.0, 2.0, 3.0] {
            let mut r = RunRecorder::new();
            r.record_at("m", 0, 0.0, v);
            r.record_at("m", 5, 0.0, v * 10.0);
            runs.push(r);
        }
        let refs: Vec<&RunRecorder> = runs.iter().collect();
        let q = quartiles_across_runs(&refs, "m");
        assert_eq!(q.steps, vec![0, 5]);
        assert_eq!(q.median, vec![2.0, 20.0]);
        assert_eq!(q.q1, vec![1.5, 15.0]);
        assert_eq!(q.q3, vec![2.5, 25.0]);
    }

    #[test]
    fn partial_steps_dropped() {
        let mut a = RunRecorder::new();
        a.record_at("m", 0, 0.0, 1.0);
        a.record_at("m", 1, 0.0, 1.0);
        let mut b = RunRecorder::new();
        b.record_at("m", 0, 0.0, 2.0);
        let q = quartiles_across_runs(&[&a, &b], "m");
        assert_eq!(q.steps, vec![0]); // step 1 missing from run b
    }

    #[test]
    fn csv_writers() {
        let dir = std::env::temp_dir().join(format!("issgd-metrics-{}", std::process::id()));
        let s = QuartileSeries {
            steps: vec![0, 1],
            q1: vec![0.1, 0.2],
            median: vec![0.5, 0.6],
            q3: vec![0.9, 1.0],
        };
        let p1 = dir.join("one.csv");
        write_quartile_csv(&p1, &s).unwrap();
        let text = std::fs::read_to_string(&p1).unwrap();
        assert!(text.starts_with("step,q1,median,q3\n0,0.1,0.5,0.9"));
        let p2 = dir.join("fig.csv");
        write_figure_csv(&p2, &[("a", &s), ("b", &s)]).unwrap();
        let text = std::fs::read_to_string(&p2).unwrap();
        assert!(text.contains("a_q1,a_median,a_q3,b_q1"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn json_export_parses() {
        let mut r = RunRecorder::new();
        r.record_at("loss", 3, 1.5, 0.25);
        let j = r.to_json();
        let arr = j.get("loss").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_arr().unwrap()[0].as_usize().unwrap(), 3);
    }
}
