//! # issgd — Distributed Importance Sampling SGD
//!
//! A rust + JAX + Pallas reproduction of *"Variance Reduction in SGD by
//! Distributed Importance Sampling"* (Alain, Lamb, Sankar, Courville,
//! Bengio; arXiv 1511.06481).
//!
//! Architecture (three layers, python never on the training path):
//!
//! * **L3 (this crate)** — the distributed coordinator: master ISSGD loop,
//!   worker scoring loops, the weight-store "database" actor, samplers,
//!   variance monitors, experiments and CLI.
//! * **L2** — the permutation-invariant MLP with manual backprop, written
//!   in JAX (`python/compile/model.py`) and AOT-lowered to HLO text.
//! * **L1** — Pallas kernels for the per-example gradient-norm trick
//!   (Proposition 1) and the fused dense layer
//!   (`python/compile/kernels/`).
//!
//! Start with [`runtime::Engine`] to load artifacts and
//! [`coordinator::Cluster`] to run the paper's master/worker/database
//! topology; see `examples/quickstart.rs` for the 60-second tour.

// Clippy baseline for the `-D warnings` CI gate.  These lints fire on
// long-standing idioms in this crate (index loops over parallel arrays,
// the big `Response` enum, builder-ish constructors returning `Arc`);
// they are allowed wholesale so the gate can reject *new* warning
// classes.  Shrink this list, don't grow it.
#![allow(clippy::collapsible_else_if)]
#![allow(clippy::collapsible_if)]
#![allow(clippy::comparison_chain)]
#![allow(clippy::large_enum_variant)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::new_ret_no_self)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::type_complexity)]
#![allow(clippy::uninlined_format_args)]

pub mod baseline;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod sampler;
pub mod telemetry;
pub mod util;
pub mod variance;
pub mod weightstore;
