//! Figure 2: training loss (top) and training prediction error (bottom)
//! for ISSGD vs regular SGD, under the paper's two hyperparameter
//! settings — (a) lr 0.01 / smoothing +10, (b) lr 0.001 / smoothing +1.
//! Median + quartiles across seeds.

use anyhow::Result;

use crate::baseline::sgd_twin;
use crate::config::RunConfig;
use crate::metrics::write_figure_csv;
use crate::runtime::Engine;

use super::runner::{engine_for, ExperimentScale, MultiRun};
use super::results_dir;

/// The four runs shared by figures 2, 3 and table 1.
pub struct SettingsRuns {
    pub a_issgd: MultiRun,
    pub a_sgd: MultiRun,
    pub b_issgd: MultiRun,
    pub b_sgd: MultiRun,
}

/// Run ISSGD + SGD under both §5 hyperparameter settings.
pub fn run_settings(scale: &ExperimentScale, engine: &Engine) -> Result<SettingsRuns> {
    let a = scale.apply(RunConfig::setting_a());
    let b = scale.apply(RunConfig::setting_b());
    Ok(SettingsRuns {
        a_issgd: MultiRun::run(&a, engine, scale.seeds, "fig2a issgd")?,
        a_sgd: MultiRun::run(&sgd_twin(&a), engine, scale.seeds, "fig2a sgd")?,
        b_issgd: MultiRun::run(&b, engine, scale.seeds, "fig2b issgd")?,
        b_sgd: MultiRun::run(&sgd_twin(&b), engine, scale.seeds, "fig2b sgd")?,
    })
}

/// Emit fig2 CSVs + stdout summary from pre-computed runs.
pub fn emit(runs: &SettingsRuns) -> Result<()> {
    let dir = results_dir();
    for (panel, issgd, sgd) in [
        ("a", &runs.a_issgd, &runs.a_sgd),
        ("b", &runs.b_issgd, &runs.b_sgd),
    ] {
        for (metric, fname) in in_panels(panel) {
            let is_q = issgd.quartiles(metric);
            let sgd_q = sgd.quartiles(metric);
            write_figure_csv(&dir.join(fname), &[("issgd", &is_q), ("sgd", &sgd_q)])?;
        }
        let is_final = issgd
            .quartiles("eval_train_loss")
            .median
            .last()
            .copied()
            .unwrap_or(f64::NAN);
        let sgd_final = sgd
            .quartiles("eval_train_loss")
            .median
            .last()
            .copied()
            .unwrap_or(f64::NAN);
        println!(
            "fig2{panel}: final median train loss  ISSGD {is_final:.4}  SGD {sgd_final:.4}  (paper: ISSGD reaches lower loss faster)"
        );
    }
    Ok(())
}

fn in_panels(panel: &str) -> Vec<(&'static str, String)> {
    vec![
        ("eval_train_loss", format!("fig2{panel}_train_loss.csv")),
        ("eval_train_err", format!("fig2{panel}_train_err.csv")),
    ]
}

/// Standalone driver.
pub fn run(scale: &ExperimentScale) -> Result<SettingsRuns> {
    let engine = engine_for(scale)?;
    let runs = run_settings(scale, &engine)?;
    emit(&runs)?;
    Ok(runs)
}
