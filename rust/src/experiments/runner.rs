//! Shared multi-seed experiment machinery.

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::{run_sim_with_engine, SimOutcome};
use crate::metrics::{quartiles_across_runs, QuartileSeries, RunRecorder};
use crate::runtime::{artifacts_dir, Engine};
use crate::log_info;

/// Scale knobs shared by all drivers: the paper ran 50 seeds for hours on
/// four GPUs; the default here is sized for a single-core CPU box.  Drivers
/// multiply their own step counts off `steps`.
#[derive(Debug, Clone)]
pub struct ExperimentScale {
    pub seeds: u64,
    pub steps: u64,
    pub n_examples: usize,
    pub model: String,
    /// Run the ASGD/peer arms through the live threaded topology
    /// (`run_peer_live`, lockstep for seed-reproducibility) instead of the
    /// round-robin sim.
    pub live_peers: bool,
    /// With `live_peers`: back each arm/seed with a durable on-disk store
    /// under this directory (`<dir>/<arm>-s<seed>`) instead of RAM.
    pub store_path: Option<String>,
}

impl Default for ExperimentScale {
    fn default() -> Self {
        ExperimentScale {
            seeds: 5,
            steps: 300,
            n_examples: 2048,
            model: "small".into(),
            live_peers: false,
            store_path: None,
        }
    }
}

impl ExperimentScale {
    /// Quick smoke scale against tiny artifacts (CI/tests).
    pub fn smoke() -> Self {
        ExperimentScale {
            seeds: 2,
            steps: 40,
            n_examples: 512,
            model: "tiny".into(),
            live_peers: false,
            store_path: None,
        }
    }

    /// Apply the scale to a config preset.
    pub fn apply(&self, mut cfg: RunConfig) -> RunConfig {
        cfg.steps = self.steps;
        cfg.n_examples = self.n_examples;
        cfg.model = self.model.clone();
        cfg
    }
}

/// The result of running one config across seeds.
pub struct MultiRun {
    pub recorders: Vec<RunRecorder>,
    pub outcomes: Vec<SimOutcome>,
}

impl MultiRun {
    /// Run `cfg` once per seed (seed = base + i), reusing one engine.
    pub fn run(cfg: &RunConfig, engine: &Engine, seeds: u64, label: &str) -> Result<MultiRun> {
        let mut recorders = Vec::new();
        let mut outcomes = Vec::new();
        for s in 0..seeds {
            let mut c = cfg.clone();
            c.seed = cfg.seed + s;
            let out = run_sim_with_engine(&c, engine)?;
            log_info!(
                "exp",
                "{label} seed {s}: final train/test err {:.4}/{:.4}",
                out.final_err.0,
                out.final_err.2
            );
            recorders.push(out.rec.clone());
            outcomes.push(out);
        }
        Ok(MultiRun {
            recorders,
            outcomes,
        })
    }

    /// Median/quartile series of a metric across the seeds.
    pub fn quartiles(&self, metric: &str) -> QuartileSeries {
        let refs: Vec<&RunRecorder> = self.recorders.iter().collect();
        quartiles_across_runs(&refs, metric)
    }

    /// Per-seed tail means of a metric (the Table-1 statistic).
    pub fn tail_means(&self, metric: &str, frac: f64) -> Vec<f64> {
        self.recorders
            .iter()
            .filter_map(|r| r.tail_mean(metric, frac))
            .collect()
    }
}

/// Load the engine for a scale (helper shared by drivers).
pub fn engine_for(scale: &ExperimentScale) -> Result<Engine> {
    Engine::load(&artifacts_dir(&scale.model))
}

/// Mean of a slice (empty-safe).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        f64::NAN
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}
