//! Shared multi-seed experiment machinery.

use anyhow::Result;

use crate::config::{RunConfig, TrainerKind};
use crate::coordinator::{run_sim_with_engine, SimOutcome};
use crate::metrics::{quartiles_across_runs, QuartileSeries, RunRecorder};
use crate::runtime::{artifacts_dir, Engine};
use crate::sampler::strategy::StrategyKind;
use crate::log_info;

/// Scale knobs shared by all drivers: the paper ran 50 seeds for hours on
/// four GPUs; the default here is sized for a single-core CPU box.  Drivers
/// multiply their own step counts off `steps`.
#[derive(Debug, Clone)]
pub struct ExperimentScale {
    pub seeds: u64,
    pub steps: u64,
    pub n_examples: usize,
    pub model: String,
    /// Run the ASGD/peer arms through the live threaded topology
    /// (`run_peer_live`, lockstep for seed-reproducibility) instead of the
    /// round-robin sim.
    pub live_peers: bool,
    /// With `live_peers`: back each arm/seed with a durable on-disk store
    /// under this directory (`<dir>/<arm>-s<seed>`) instead of RAM.
    pub store_path: Option<String>,
}

impl Default for ExperimentScale {
    fn default() -> Self {
        ExperimentScale {
            seeds: 5,
            steps: 300,
            n_examples: 2048,
            model: "small".into(),
            live_peers: false,
            store_path: None,
        }
    }
}

impl ExperimentScale {
    /// Quick smoke scale against tiny artifacts (CI/tests).
    pub fn smoke() -> Self {
        ExperimentScale {
            seeds: 2,
            steps: 40,
            n_examples: 512,
            model: "tiny".into(),
            live_peers: false,
            store_path: None,
        }
    }

    /// Apply the scale to a config preset.
    pub fn apply(&self, mut cfg: RunConfig) -> RunConfig {
        cfg.steps = self.steps;
        cfg.n_examples = self.n_examples;
        cfg.model = self.model.clone();
        cfg
    }

    /// Scale a preset, then overlay one arm's overrides — the one-line
    /// entry every driver's arm loop goes through.
    pub fn arm(&self, preset: RunConfig, overrides: &ArmOverrides) -> RunConfig {
        overrides.apply(self.apply(preset))
    }
}

/// Per-arm config overrides, applied on top of a scaled preset.
///
/// Experiment drivers used to hand-mutate `RunConfig` fields positionally
/// inside each arm loop (a tuple of anonymous values per arm, with a
/// different tuple shape in every driver); this struct names each override
/// once, so arms across fig2/fig3/fig4/table1/asgd/staleness/adaptive and
/// the strategy matrix read the same way.  `None` keeps the preset/scale
/// value; the double-`Option` fields (`staleness`, `adaptive_entropy`)
/// distinguish "don't touch" from "explicitly disable".
#[derive(Debug, Clone, Default)]
pub struct ArmOverrides {
    pub strategy: Option<StrategyKind>,
    pub trainer: Option<TrainerKind>,
    /// `Some(None)` explicitly disables the §B.1 filter.
    pub staleness: Option<Option<u64>>,
    pub n_workers: Option<usize>,
    pub worker_batches_per_step: Option<usize>,
    pub param_push_every: Option<u64>,
    pub smoothing: Option<f64>,
    /// `Some(None)` explicitly pins the fixed constant.
    pub adaptive_entropy: Option<Option<f64>>,
    pub monitor_every: Option<u64>,
    pub monitor_alt_smoothing: Option<f64>,
}

impl ArmOverrides {
    pub fn apply(&self, mut cfg: RunConfig) -> RunConfig {
        if let Some(s) = self.strategy {
            cfg.strategy = s;
        }
        if let Some(t) = self.trainer {
            cfg.trainer = t;
        }
        if let Some(t) = self.staleness {
            cfg.staleness_threshold = t;
        }
        if let Some(w) = self.n_workers {
            cfg.n_workers = w;
        }
        if let Some(b) = self.worker_batches_per_step {
            cfg.worker_batches_per_step = b;
        }
        if let Some(p) = self.param_push_every {
            cfg.param_push_every = p;
        }
        if let Some(c) = self.smoothing {
            cfg.smoothing = c;
        }
        if let Some(a) = self.adaptive_entropy {
            cfg.adaptive_entropy = a;
        }
        if let Some(m) = self.monitor_every {
            cfg.monitor_every = m;
        }
        if let Some(m) = self.monitor_alt_smoothing {
            cfg.monitor_alt_smoothing = m;
        }
        cfg
    }
}

/// The result of running one config across seeds.
pub struct MultiRun {
    pub recorders: Vec<RunRecorder>,
    pub outcomes: Vec<SimOutcome>,
}

impl MultiRun {
    /// Run `cfg` once per seed (seed = base + i), reusing one engine.
    pub fn run(cfg: &RunConfig, engine: &Engine, seeds: u64, label: &str) -> Result<MultiRun> {
        let mut recorders = Vec::new();
        let mut outcomes = Vec::new();
        for s in 0..seeds {
            let mut c = cfg.clone();
            c.seed = cfg.seed + s;
            let out = run_sim_with_engine(&c, engine)?;
            log_info!(
                "exp",
                "{label} seed {s}: final train/test err {:.4}/{:.4}",
                out.final_err.0,
                out.final_err.2
            );
            recorders.push(out.rec.clone());
            outcomes.push(out);
        }
        Ok(MultiRun {
            recorders,
            outcomes,
        })
    }

    /// Median/quartile series of a metric across the seeds.
    pub fn quartiles(&self, metric: &str) -> QuartileSeries {
        let refs: Vec<&RunRecorder> = self.recorders.iter().collect();
        quartiles_across_runs(&refs, metric)
    }

    /// Per-seed tail means of a metric (the Table-1 statistic).
    pub fn tail_means(&self, metric: &str, frac: f64) -> Vec<f64> {
        self.recorders
            .iter()
            .filter_map(|r| r.tail_mean(metric, frac))
            .collect()
    }
}

/// Load the engine for a scale (helper shared by drivers).
pub fn engine_for(scale: &ExperimentScale) -> Result<Engine> {
    Engine::load(&artifacts_dir(&scale.model))
}

/// Mean of a slice (empty-safe).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        f64::NAN
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arm_overrides_touch_only_set_fields() {
        let scale = ExperimentScale::smoke();
        let base = scale.apply(RunConfig::setting_b());
        let arm = ArmOverrides {
            strategy: Some(StrategyKind::Exp3),
            staleness: Some(Some(7)),
            n_workers: Some(5),
            ..Default::default()
        };
        let cfg = scale.arm(RunConfig::setting_b(), &arm);
        assert_eq!(cfg.strategy, StrategyKind::Exp3);
        assert_eq!(cfg.staleness_threshold, Some(7));
        assert_eq!(cfg.n_workers, 5);
        // Everything unset keeps the scaled-preset value.
        assert_eq!(cfg.steps, base.steps);
        assert_eq!(cfg.smoothing, base.smoothing);
        assert_eq!(cfg.trainer, base.trainer);
        // An empty override set is the identity.
        let id = scale.arm(RunConfig::setting_b(), &ArmOverrides::default());
        assert_eq!(id.staleness_threshold, base.staleness_threshold);
        assert_eq!(id.strategy, base.strategy);
    }

    #[test]
    fn arm_overrides_double_option_disables_explicitly() {
        let cfg = ArmOverrides {
            staleness: Some(None),
            adaptive_entropy: Some(None),
            ..Default::default()
        }
        .apply(RunConfig {
            staleness_threshold: Some(4),
            adaptive_entropy: Some(0.9),
            ..RunConfig::default()
        });
        assert_eq!(cfg.staleness_threshold, None);
        assert_eq!(cfg.adaptive_entropy, None);
    }
}
