//! Strategy shoot-out: every registered [`StrategyKind`] under identical
//! seeds, the same staleness regime, and (for the chaos arms) the same
//! `FaultyStore` schedule of withheld and truncated delta fetches.
//!
//! One table answers the ISSUE-6 question directly: does the paper's
//! unbiased grad-norm proposal (arXiv 1511.06481) actually beat the
//! biased shortcuts — loss-ranked rejection (Katharopoulos & Fleuret
//! 2018), a tempered power proposal (K&F 2017), and an EXP3-style
//! bandit posting (Bouchard et al. 2015) — once the score pipeline is
//! held fixed?  Columns: tail-mean √Tr(Σ) of the *stale* proposal (the
//! variance the master actually trains under), tail-mean effective
//! sample size, and final test error, each averaged across seeds.
//!
//! The chaos arms re-run the same configs against a `MemStore` wrapped
//! in a deterministic [`FaultyStore`] (20% withheld fetches, 20%
//! truncated deltas, no injected errors — the master treats store
//! errors at construction as fatal), so the table also shows which
//! strategies degrade gracefully when the weight database misbehaves.

use std::sync::Arc;

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::{run_sim_with_store, Master};
use crate::sampler::strategy::StrategyKind;
use crate::weightstore::faulty::{FaultSpec, FaultyStore};
use crate::weightstore::{MemStore, WeightStore};

use super::runner::{engine_for, mean, ArmOverrides, ExperimentScale};
use super::results_dir;

/// Withhold / truncate probability for the chaos arms.
const CHAOS_P: f64 = 0.2;

pub struct MatrixRow {
    pub strategy: &'static str,
    pub unbiased: bool,
    pub chaos: bool,
    /// Tail-mean √Tr(Σ) under the actual (stale) proposal.
    pub sqrt_var: f64,
    /// Tail-mean effective-sample-size ratio of the proposal.
    pub ess: f64,
    /// Final test error, seed-averaged.
    pub test_err: f64,
}

pub fn run_matrix(scale: &ExperimentScale) -> Result<Vec<MatrixRow>> {
    let engine = engine_for(scale)?;
    let mut rows = Vec::new();
    for &kind in StrategyKind::all() {
        for chaos in [false, true] {
            let arm = ArmOverrides {
                strategy: Some(kind),
                // A finite threshold so the staleness filter participates
                // (the shoot-out should rank strategies under the regime
                // the paper actually trains in, not the ideal one).
                staleness: Some(Some(8)),
                monitor_every: Some((scale.steps / 8).max(1)),
                ..Default::default()
            };
            let (mut vars, mut esses, mut terrs) = (Vec::new(), Vec::new(), Vec::new());
            for s in 0..scale.seeds {
                let mut cfg = scale.arm(RunConfig::setting_b(), &arm);
                cfg.seed += s;
                let mem: Arc<dyn WeightStore> =
                    Arc::new(MemStore::new(Master::store_size(&cfg), cfg.init_weight));
                let store = if chaos {
                    let spec = FaultSpec::quiet(cfg.seed)
                        .with_withholding(CHAOS_P)
                        .with_partial_deltas(CHAOS_P);
                    Arc::new(FaultyStore::new(mem, spec)) as Arc<dyn WeightStore>
                } else {
                    mem
                };
                let out = run_sim_with_store(&cfg, &engine, store)?;
                if let Some(v) = out.rec.tail_mean("var_stale_sqrt", 0.5) {
                    vars.push(v);
                }
                if let Some(e) = out.rec.tail_mean("ess", 0.5) {
                    esses.push(e);
                }
                terrs.push(out.final_err.2);
            }
            rows.push(MatrixRow {
                strategy: kind.name(),
                unbiased: kind.strategy().unbiased(),
                chaos,
                sqrt_var: mean(&vars),
                ess: mean(&esses),
                test_err: mean(&terrs),
            });
        }
    }
    Ok(rows)
}

pub fn emit(rows: &[MatrixRow]) -> Result<()> {
    println!("\nISSUE-6 strategy matrix (identical seeds, staleness 8)");
    println!("{:-<76}", "");
    println!(
        "{:<12} {:>9} {:>7} {:>12} {:>10} {:>10}",
        "strategy", "unbiased", "chaos", "sqrt_var", "ess", "test_err"
    );
    for r in rows {
        println!(
            "{:<12} {:>9} {:>7} {:>12.4} {:>10.3} {:>10.4}",
            r.strategy,
            if r.unbiased { "yes" } else { "no" },
            if r.chaos { "yes" } else { "no" },
            r.sqrt_var,
            r.ess,
            r.test_err
        );
    }
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    let mut csv = String::from("strategy,unbiased,chaos,sqrt_var,ess,test_err\n");
    for r in rows {
        csv.push_str(&format!(
            "{},{},{},{},{},{}\n",
            r.strategy, r.unbiased, r.chaos, r.sqrt_var, r.ess, r.test_err
        ));
    }
    std::fs::write(dir.join("strategy_matrix.csv"), csv)?;
    Ok(())
}

pub fn run(scale: &ExperimentScale) -> Result<Vec<MatrixRow>> {
    let rows = run_matrix(scale)?;
    emit(&rows)?;
    Ok(rows)
}
