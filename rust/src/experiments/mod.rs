//! Experiment drivers: one per paper table/figure (DESIGN.md §5).
//!
//! Every driver runs multi-seed simulations, aggregates median/quartiles
//! across seeds (the paper's 50-run tubes; seed count configurable), and
//! writes one CSV per panel under the results directory, printing the
//! paper-shaped summary rows to stdout.  `cargo bench` wraps the same
//! drivers at reduced scale (see `rust/benches/`).

pub mod adaptive;
pub mod asgd;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod runner;
pub mod staleness;
pub mod strategy_matrix;
pub mod table1;

pub use runner::{ExperimentScale, MultiRun};

use std::path::PathBuf;

/// Where experiment CSVs go (`ISSGD_RESULTS` env var overrides).
pub fn results_dir() -> PathBuf {
    PathBuf::from(std::env::var("ISSGD_RESULTS").unwrap_or_else(|_| "results".to_string()))
}
