//! Table 1: final test prediction error for SGD (ours) and ISSGD,
//! averaged over the final 10% of recorded iterations, hyperparameter
//! setting chosen by validation error — exactly the paper's protocol.

use anyhow::Result;

use super::fig2::{run_settings, SettingsRuns};
use super::runner::{engine_for, mean, ExperimentScale, MultiRun};

pub struct Table1Row {
    pub method: &'static str,
    pub setting: &'static str,
    pub valid_err: f64,
    pub test_err: f64,
}

/// Pick the better setting per method by validation error, report test.
pub fn compute(runs: &SettingsRuns) -> Vec<Table1Row> {
    let pick = |name: &'static str, a: &MultiRun, b: &MultiRun| -> Table1Row {
        let stat = |mr: &MultiRun, metric: &str| mean(&mr.tail_means(metric, 0.1));
        // Validation = final-10% average of test split stand-in: we record
        // valid via final_err; use eval_test_err tail as test statistic and
        // outcome valid errs for selection.
        let a_valid = mean(
            &a.outcomes
                .iter()
                .map(|o| o.final_err.1)
                .collect::<Vec<_>>(),
        );
        let b_valid = mean(
            &b.outcomes
                .iter()
                .map(|o| o.final_err.1)
                .collect::<Vec<_>>(),
        );
        if a_valid <= b_valid {
            Table1Row {
                method: name,
                setting: "a (lr .01, +10)",
                valid_err: a_valid,
                test_err: stat(a, "eval_test_err"),
            }
        } else {
            Table1Row {
                method: name,
                setting: "b (lr .001, +1)",
                valid_err: b_valid,
                test_err: stat(b, "eval_test_err"),
            }
        }
    };
    vec![
        pick("SGD (ours)", &runs.a_sgd, &runs.b_sgd),
        pick("Importance Sampling SGD", &runs.a_issgd, &runs.b_issgd),
    ]
}

pub fn emit(runs: &SettingsRuns) -> Result<Vec<Table1Row>> {
    let rows = compute(runs);
    println!("\nTable 1: test error (final-10% average, setting by validation)");
    println!("{:-<78}", "");
    println!("{:<28} {:<18} {:>12} {:>12}", "Model", "Setting", "Valid err", "Test err");
    for r in &rows {
        println!(
            "{:<28} {:<18} {:>12.4} {:>12.4}",
            r.method, r.setting, r.valid_err, r.test_err
        );
    }
    println!(
        "(paper: SGD 0.0754 vs ISSGD 0.0756 on permutation-invariant SVHN — \
         near-identical final errors; the win is optimisation speed)"
    );
    // Persist as CSV too.
    let dir = super::results_dir();
    std::fs::create_dir_all(&dir)?;
    let mut csv = String::from("method,setting,valid_err,test_err\n");
    for r in &rows {
        csv.push_str(&format!(
            "{},{},{},{}\n",
            r.method, r.setting, r.valid_err, r.test_err
        ));
    }
    std::fs::write(dir.join("table1.csv"), csv)?;
    Ok(rows)
}

pub fn run(scale: &ExperimentScale) -> Result<Vec<Table1Row>> {
    let engine = engine_for(scale)?;
    let runs = run_settings(scale, &engine)?;
    emit(&runs)
}
