//! §B.3 extension ablation: fixed vs entropy-targeted adaptive smoothing.
//!
//! The paper uses a fixed additive constant and *suggests* an adaptive
//! entropy-targeted scheme ("this was not explored").  We built it
//! (`sampler::adaptive`), so we ablate it: ISSGD runs with fixed constants
//! {0, 1, 10} against adaptive targets {0.7, 0.9, 0.97}, reporting final
//! loss, the realised smoothing constants, and the proposal's effective
//! sample size.

use anyhow::Result;

use crate::config::RunConfig;
use crate::metrics::write_quartile_csv;

use super::runner::{engine_for, mean, ArmOverrides, ExperimentScale, MultiRun};
use super::results_dir;

pub struct AdaptiveRow {
    pub label: String,
    pub final_loss: f64,
    pub mean_c: f64,
    pub mean_ess: f64,
}

pub fn run_ablation(scale: &ExperimentScale) -> Result<Vec<AdaptiveRow>> {
    let engine = engine_for(scale)?;
    let mut rows = Vec::new();
    let fixed = |c: f64| ArmOverrides {
        smoothing: Some(c),
        adaptive_entropy: Some(None),
        ..Default::default()
    };
    let adaptive = |h: f64| ArmOverrides {
        smoothing: Some(0.0),
        adaptive_entropy: Some(Some(h)),
        ..Default::default()
    };
    let arms: Vec<(String, ArmOverrides)> = vec![
        ("fixed +0".into(), fixed(0.0)),
        ("fixed +1".into(), fixed(1.0)),
        ("fixed +10".into(), fixed(10.0)),
        ("adaptive H*=0.7".into(), adaptive(0.7)),
        ("adaptive H*=0.9".into(), adaptive(0.9)),
        ("adaptive H*=0.97".into(), adaptive(0.97)),
    ];
    for (label, arm) in arms {
        let cfg = scale.arm(RunConfig::setting_b(), &arm);
        let mr = MultiRun::run(&cfg, &engine, scale.seeds.min(3), &label)?;
        let final_loss = mean(&mr.tail_means("train_loss", 0.1));
        let mean_c = if cfg.adaptive_entropy.is_some() {
            mean(&mr.tail_means("smoothing_c", 0.5))
        } else {
            cfg.smoothing
        };
        let mean_ess = mean(&mr.tail_means("ess", 0.5));
        if label.starts_with("adaptive H*=0.9") {
            let q = mr.quartiles("smoothing_c");
            if !q.steps.is_empty() {
                write_quartile_csv(&results_dir().join("adaptive_smoothing_c.csv"), &q)?;
            }
        }
        rows.push(AdaptiveRow {
            label,
            final_loss,
            mean_c,
            mean_ess,
        });
    }
    Ok(rows)
}

pub fn emit(rows: &[AdaptiveRow]) -> Result<()> {
    println!("\n§B.3 extension: fixed vs entropy-targeted adaptive smoothing");
    println!("{:-<66}", "");
    println!(
        "{:<20} {:>12} {:>14} {:>12}",
        "smoothing", "final loss", "mean c (tail)", "mean ESS"
    );
    for r in rows {
        println!(
            "{:<20} {:>12.4} {:>14.4} {:>12.3}",
            r.label, r.final_loss, r.mean_c, r.mean_ess
        );
    }
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    let mut csv = String::from("smoothing,final_loss,mean_c,mean_ess\n");
    for r in rows {
        csv.push_str(&format!(
            "{},{},{},{}\n",
            r.label, r.final_loss, r.mean_c, r.mean_ess
        ));
    }
    std::fs::write(dir.join("adaptive_smoothing.csv"), csv)?;
    Ok(())
}

pub fn run(scale: &ExperimentScale) -> Result<Vec<AdaptiveRow>> {
    let rows = run_ablation(scale)?;
    emit(&rows)?;
    Ok(rows)
}
