//! §B.1 staleness ablation: how the staleness-filter threshold and the
//! worker count shape the kept-weight fraction and the variance penalty.
//!
//! Paper quote: "with 3 workers, a staleness threshold of 4 seconds leads
//! to 15% of the probability weights being kept"; "adding more workers
//! naturally lowers the average staleness".  We sweep worker counts and
//! (version-unit) thresholds and report kept fractions plus the stale/ideal
//! variance ratio, reproducing both qualitative claims.

use anyhow::Result;

use crate::config::RunConfig;
use crate::metrics::write_quartile_csv;

use super::runner::{engine_for, mean, ArmOverrides, ExperimentScale, MultiRun};
use super::results_dir;

pub struct StalenessRow {
    pub workers: usize,
    pub threshold: Option<u64>,
    pub kept_frac: f64,
    pub sampled_lag: f64,
}

pub fn run_sweep(
    scale: &ExperimentScale,
    worker_counts: &[usize],
    thresholds: &[Option<u64>],
) -> Result<Vec<StalenessRow>> {
    let engine = engine_for(scale)?;
    let mut rows = Vec::new();
    for &workers in worker_counts {
        for &threshold in thresholds {
            // The paper's staleness regime has workers much slower than
            // the master (570k examples / 3 GPUs): emulate by scoring one
            // batch per worker per step and publishing params every step,
            // so weight ages span several versions and thresholds bite.
            let arm = ArmOverrides {
                n_workers: Some(workers),
                staleness: Some(threshold),
                worker_batches_per_step: Some(1),
                param_push_every: Some(1),
                ..Default::default()
            };
            let cfg = scale.arm(RunConfig::setting_b(), &arm);
            let mr = MultiRun::run(
                &cfg,
                &engine,
                scale.seeds.min(3),
                &format!("staleness w={workers} t={threshold:?}"),
            )?;
            let kept = mean(&mr.tail_means("kept_frac", 0.5));
            let lag = mean(&mr.tail_means("sampled_version_lag", 0.5));
            // Also persist the kept-fraction trajectory of the first combo
            // for plotting.
            if workers == worker_counts[0] {
                let q = mr.quartiles("kept_frac");
                if !q.steps.is_empty() {
                    write_quartile_csv(
                        &results_dir().join(format!(
                            "staleness_kept_w{workers}_t{}.csv",
                            threshold.map(|t| t.to_string()).unwrap_or("off".into())
                        )),
                        &q,
                    )?;
                }
            }
            rows.push(StalenessRow {
                workers,
                threshold,
                kept_frac: kept,
                sampled_lag: lag,
            });
        }
    }
    Ok(rows)
}

pub fn emit(rows: &[StalenessRow]) -> Result<()> {
    println!("\n§B.1 staleness sweep (version-unit thresholds)");
    println!("{:-<64}", "");
    println!(
        "{:>8} {:>12} {:>12} {:>14}",
        "workers", "threshold", "kept_frac", "sampled_lag"
    );
    for r in rows {
        println!(
            "{:>8} {:>12} {:>12.3} {:>14.3}",
            r.workers,
            r.threshold.map(|t| t.to_string()).unwrap_or("off".into()),
            r.kept_frac,
            r.sampled_lag
        );
    }
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    let mut csv = String::from("workers,threshold,kept_frac,sampled_lag\n");
    for r in rows {
        csv.push_str(&format!(
            "{},{},{},{}\n",
            r.workers,
            r.threshold.map(|t| t.to_string()).unwrap_or("off".into()),
            r.kept_frac,
            r.sampled_lag
        ));
    }
    std::fs::write(dir.join("staleness_sweep.csv"), csv)?;
    Ok(())
}

pub fn run(scale: &ExperimentScale) -> Result<()> {
    let rows = run_sweep(scale, &[1, 2, 3, 8], &[None, Some(0), Some(1), Some(2)])?;
    emit(&rows)
}
