//! Figure 4: √Tr(Σ(q)) during ISSGD training for the proposals
//! q_IDEAL ("ISSGD, ideal"), q_UNIF ("SGD, ideal"), and q_STALE with the
//! actual and an alternate smoothing constant — both §5 settings.
//!
//! The monitor re-scores the full training split under current parameters
//! at each sample point (expensive; cadence = steps/12 by default).

use anyhow::Result;

use crate::config::RunConfig;
use crate::metrics::write_figure_csv;

use super::runner::{engine_for, ArmOverrides, ExperimentScale, MultiRun};
use super::results_dir;

pub struct Fig4Runs {
    pub a: MultiRun,
    pub b: MultiRun,
}

pub fn run_monitored(scale: &ExperimentScale) -> Result<Fig4Runs> {
    let engine = engine_for(scale)?;
    // Fig-4 shows the opposite smoothing constant as the alternate curve.
    let monitored = |alt: f64| ArmOverrides {
        monitor_every: Some((scale.steps / 12).max(1)),
        monitor_alt_smoothing: Some(alt),
        ..Default::default()
    };
    let a = scale.arm(RunConfig::setting_a(), &monitored(1.0));
    let b = scale.arm(RunConfig::setting_b(), &monitored(10.0));
    Ok(Fig4Runs {
        a: MultiRun::run(&a, &engine, scale.seeds, "fig4a")?,
        b: MultiRun::run(&b, &engine, scale.seeds, "fig4b")?,
    })
}

pub fn emit(runs: &Fig4Runs) -> Result<()> {
    let dir = results_dir();
    for (panel, mr) in [("a", &runs.a), ("b", &runs.b)] {
        let ideal = mr.quartiles("var_ideal_sqrt");
        let unif = mr.quartiles("var_unif_sqrt");
        let stale = mr.quartiles("var_stale_sqrt");
        let stale_alt = mr.quartiles("var_stale_alt_sqrt");
        write_figure_csv(
            &dir.join(format!("fig4{panel}_sqrt_trace.csv")),
            &[
                ("issgd_ideal", &ideal),
                ("sgd_ideal", &unif),
                ("stale_actual", &stale),
                ("stale_alt", &stale_alt),
            ],
        )?;
        // Paper claim: ideal ≤ stale ≤ unif at (almost) every checkpoint.
        let mut ordering_ok = 0usize;
        let mut total = 0usize;
        for i in 0..ideal.steps.len() {
            total += 1;
            if ideal.median[i] <= stale.median[i] + 1e-9
                && stale.median[i] <= unif.median[i] + 1e-9
            {
                ordering_ok += 1;
            }
        }
        let last = ideal.steps.len().saturating_sub(1);
        println!(
            "fig4{panel}: sqrt-trace at final checkpoint — ideal {:.4}  stale {:.4}  unif {:.4}; \
             ordering ideal<=stale<=unif held at {ordering_ok}/{total} checkpoints",
            ideal.median.get(last).copied().unwrap_or(f64::NAN),
            stale.median.get(last).copied().unwrap_or(f64::NAN),
            unif.median.get(last).copied().unwrap_or(f64::NAN),
        );
    }
    Ok(())
}

pub fn run(scale: &ExperimentScale) -> Result<()> {
    let runs = run_monitored(scale)?;
    emit(&runs)
}
