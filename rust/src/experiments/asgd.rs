//! §6 extension experiment: ISSGD vs ASGD vs the paper's recommended
//! ISSGD+ASGD combination, at a matched gradient-computation budget.
//!
//! The paper explicitly avoids this comparison ("we are not currently in
//! possession of a good production-quality ASGD implementation") and
//! poses it as future work; we built the parameter server
//! (`WeightStore::apply_grad`) and peer actors (`coordinator::peer`), so
//! we run it.  Four arms, same seed/data/schedule:
//!
//!   sgd        — single master, uniform minibatches (paper baseline)
//!   issgd      — master/worker/database ISSGD (the paper's method)
//!   asgd       — K peers + parameter server, uniform minibatches
//!   issgd+asgd — K peers + parameter server, importance-sampled
//!                minibatches with §6's co-computed weights
//!
//! The x-axis is total gradient computations (master steps or peer
//! contributions), so the comparison is optimization-efficiency, not
//! wall-clock on this single-core host.

use anyhow::Result;

use crate::baseline::sgd_twin;
use crate::config::{RunConfig, TrainerKind};
use crate::coordinator::peer::run_asgd_sim;
use crate::coordinator::peer_live::{run_peer_live, PeerLiveOptions};
use crate::coordinator::run_sim_with_engine;
use crate::metrics::{quartiles_across_runs, write_figure_csv, RunRecorder};

use super::runner::{engine_for, ArmOverrides, ExperimentScale};
use super::results_dir;

pub struct AsgdRow {
    pub method: &'static str,
    pub final_train_err: f64,
    pub final_test_err: f64,
    pub final_train_loss: f64,
}

pub fn run_comparison(scale: &ExperimentScale) -> Result<Vec<AsgdRow>> {
    let engine = engine_for(scale)?;
    let base = scale.apply(RunConfig::setting_b());

    let mut rows = Vec::new();
    let mut series: Vec<(&'static str, Vec<RunRecorder>)> = Vec::new();

    let solo_arm = |trainer: TrainerKind| ArmOverrides {
        trainer: Some(trainer),
        ..Default::default()
    };
    // Peer arms: 3 peers re-fetching every 4 own-steps (genuine staleness).
    let peer_arm = |trainer: TrainerKind| ArmOverrides {
        trainer: Some(trainer),
        n_workers: Some(3),
        param_push_every: Some(4),
        ..Default::default()
    };
    for (name, peers, arm) in [
        ("sgd", false, solo_arm(TrainerKind::UniformSgd)),
        ("issgd", false, solo_arm(TrainerKind::Issgd)),
        ("asgd", true, peer_arm(TrainerKind::UniformSgd)),
        ("issgd_asgd", true, peer_arm(TrainerKind::Issgd)),
    ] {
        let mut recs = Vec::new();
        let (mut errs, mut terrs, mut losses) = (Vec::new(), Vec::new(), Vec::new());
        for s in 0..scale.seeds {
            let mut cfg = arm.apply(base.clone());
            cfg.seed = base.seed + s;
            let (rec, ferr) = match peers {
                false => {
                    let cfg = if cfg.trainer == TrainerKind::UniformSgd {
                        sgd_twin(&cfg)
                    } else {
                        cfg
                    };
                    let out = run_sim_with_engine(&cfg, &engine)?;
                    (out.rec, out.final_err)
                }
                true => {
                    // Sim vs live peer topology: the live arm runs one OS
                    // thread per peer, lockstep so seeds stay comparable.
                    let out = if scale.live_peers {
                        // Optional durable backend: one store dir per
                        // arm/seed so repeated experiment runs recover
                        // (and exercise) the on-disk path.
                        let store = match &scale.store_path {
                            None => None,
                            Some(dir) => {
                                use crate::coordinator::Master;
                                use crate::weightstore::durable::DurableStore;
                                use crate::weightstore::WeightStore;
                                let path =
                                    std::path::Path::new(dir).join(format!("{name}-s{s}"));
                                let d = DurableStore::open_or_create(
                                    &path,
                                    Master::store_size(&cfg),
                                    cfg.init_weight,
                                    Default::default(),
                                )?;
                                Some(std::sync::Arc::new(d) as std::sync::Arc<dyn WeightStore>)
                            }
                        };
                        run_peer_live(
                            &cfg,
                            &PeerLiveOptions {
                                store,
                                lockstep: true,
                                deadline: Some(std::time::Duration::from_secs(600)),
                                ..PeerLiveOptions::default()
                            },
                        )?
                    } else {
                        run_asgd_sim(&cfg, &engine)?
                    };
                    (out.rec, out.final_err)
                }
            };
            losses.push(rec.tail_mean("train_loss", 0.1).unwrap_or(f64::NAN));
            errs.push(ferr.0);
            terrs.push(ferr.2);
            recs.push(rec);
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        rows.push(AsgdRow {
            method: name,
            final_train_err: mean(&errs),
            final_test_err: mean(&terrs),
            final_train_loss: mean(&losses),
        });
        series.push((name, recs));
    }

    // CSV: median train-loss trajectories of all four arms.
    let quartiles: Vec<_> = series
        .iter()
        .map(|(name, recs)| {
            let refs: Vec<&RunRecorder> = recs.iter().collect();
            (*name, quartiles_across_runs(&refs, "eval_train_loss"))
        })
        .collect();
    let named: Vec<(&str, &crate::metrics::QuartileSeries)> =
        quartiles.iter().map(|(n, q)| (*n, q)).collect();
    // Arms share the eval schedule; guard against empty series anyway.
    if named.iter().all(|(_, q)| !q.steps.is_empty())
        && named
            .iter()
            .all(|(_, q)| q.steps == named[0].1.steps)
    {
        write_figure_csv(&results_dir().join("asgd_combo_train_loss.csv"), &named)?;
    }
    Ok(rows)
}

pub fn emit(rows: &[AsgdRow]) -> Result<()> {
    println!("\n§6 extension: ISSGD × ASGD at matched gradient budget");
    println!("{:-<72}", "");
    println!(
        "{:<14} {:>16} {:>15} {:>15}",
        "method", "final train loss", "final train err", "final test err"
    );
    for r in rows {
        println!(
            "{:<14} {:>16.4} {:>15.4} {:>15.4}",
            r.method, r.final_train_loss, r.final_train_err, r.final_test_err
        );
    }
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    let mut csv = String::from("method,final_train_loss,final_train_err,final_test_err\n");
    for r in rows {
        csv.push_str(&format!(
            "{},{},{},{}\n",
            r.method, r.final_train_loss, r.final_train_err, r.final_test_err
        ));
    }
    std::fs::write(dir.join("asgd_combo.csv"), csv)?;
    Ok(())
}

pub fn run(scale: &ExperimentScale) -> Result<Vec<AsgdRow>> {
    let rows = run_comparison(scale)?;
    emit(&rows)?;
    Ok(rows)
}
