//! Figure 3: test prediction error, ISSGD vs SGD, both settings.
//! Shares runs with figure 2 (same training trajectories, different
//! evaluation split).

use anyhow::Result;

use crate::metrics::write_figure_csv;

use super::fig2::{run_settings, SettingsRuns};
use super::runner::{engine_for, ExperimentScale};
use super::results_dir;

pub fn emit(runs: &SettingsRuns) -> Result<()> {
    let dir = results_dir();
    for (panel, issgd, sgd) in [
        ("a", &runs.a_issgd, &runs.a_sgd),
        ("b", &runs.b_issgd, &runs.b_sgd),
    ] {
        let is_q = issgd.quartiles("eval_test_err");
        let sgd_q = sgd.quartiles("eval_test_err");
        write_figure_csv(
            &dir.join(format!("fig3{panel}_test_err.csv")),
            &[("issgd", &is_q), ("sgd", &sgd_q)],
        )?;
        let is_final = is_q.median.last().copied().unwrap_or(f64::NAN);
        let sgd_final = sgd_q.median.last().copied().unwrap_or(f64::NAN);
        println!(
            "fig3{panel}: final median test err  ISSGD {is_final:.4}  SGD {sgd_final:.4}"
        );
    }
    Ok(())
}

pub fn run(scale: &ExperimentScale) -> Result<()> {
    let engine = engine_for(scale)?;
    let runs = run_settings(scale, &engine)?;
    emit(&runs)
}
