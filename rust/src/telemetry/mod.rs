//! Process-wide telemetry: counters, gauges and log2-bucket latency
//! histograms behind one registry, scrapeable over the wire.
//!
//! analyze: allow-module(wallclock): latency histograms and the flight
//! recorder time wall clock by design; nothing here feeds back into
//! training decisions, so virtual-time determinism is unaffected
//!
//! The paper's claim is operational — importance sampling has to win
//! *"even in a context where the cost of synchronization across machines
//! cannot be ignored"* — so that cost must be observable on a live
//! system, not just in post-mortem `BENCH_*` artifacts.  This module is
//! the repo's `prometheus`/`metrics`-crate substitute (those are
//! unavailable offline): a zero-dependency, process-wide registry that
//! the hot paths bump through lock-free atomics and that `issgd metrics
//! <addr>` scrapes from a live `db-server` via the `FetchMetrics` opcode.
//!
//! # Metric kinds
//!
//! * [`Counter`] — monotone `u64` (`server.evictions`).
//! * [`Gauge`] — last-written `f64` (`proposal.ess`, `compact.floor`).
//! * [`Histogram`] — 64 fixed log2 buckets plus exact count/sum/max;
//!   recording is a few relaxed atomic ops, no allocation, and p50/p99
//!   are derived at snapshot time from the bucket counts (upper-bound
//!   estimates, exact `max`).
//!
//! # Naming scheme
//!
//! Dotted `subsystem.metric` names, `_ns` suffix for nanosecond
//! histograms: `server.tick_ns`, `journal.fsync_ns`, `compact.floor`,
//! `client.reconnects`, `pool.coalesced_fetches`, `proposal.ess`,
//! `peer.cursor_lag`, …  The canonical store-side set is listed in
//! [`STORE_METRICS`] and pre-registered by the server so a scrape always
//! exposes the full schema, even before the first event.
//!
//! The grammar is **enforced** by `cargo run -p xtask -- analyze` (the
//! `telemetry` lint): a metric name literal must be exactly two
//! dot-separated segments, each lowercase `snake_case` starting with a
//! letter (`[a-z][a-z0-9_]*`).  Files under `weightstore/` must also
//! declare every name in [`STORE_METRICS`] with a matching kind, and no
//! name may be used as two different instrument kinds anywhere in the
//! tree (the registry's runtime kind guard panics; the lint catches the
//! conflict before it can).
//!
//! # How to add a metric
//!
//! Call [`counter`]/[`gauge`]/[`histogram`] with a new dotted name at the
//! instrumentation site — first use registers it (the handle is
//! `&'static`; cache it in a loop-local when the site is per-tick hot).
//! Timing uses [`start`]/[`Stopwatch`] so the *call site* never touches
//! `Instant::now` — the wallclock pragma policy is that the determinism
//! lint's waiver lives here, on this module, and instrumented files stay
//! pragma-free.  If the metric belongs to the store process, add it to
//! [`STORE_METRICS`] so scrapes expose it from boot.
//!
//! # Registry vs the per-instance ad-hoc structs
//!
//! `StoreStats`, client `Stats`, `FaultStats` and `PeerStats` remain the
//! *per-instance* views their callers assert on; their increment sites
//! dual-write into this registry, which accumulates the *process-wide*
//! totals that one snapshot reports together.
//!
//! # Export formats
//!
//! [`Snapshot::to_json`] is the canonical machine format (the
//! `FetchMetrics` payload and the `--telemetry-dump` JSONL lines);
//! [`Snapshot::to_prometheus`] renders the same snapshot as a
//! Prometheus-style text exposition (`issgd metrics` default).  Counts
//! ride in JSON `f64`s, exact up to 2^53 — beyond any plausible run.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Log2 bucket count: bucket `i` holds values whose bit length is `i`
/// (bucket 0 = zero, bucket `i` = `[2^(i-1), 2^i)`), with everything of
/// 63+ bits clamped into the last bucket.
pub const HIST_BUCKETS: usize = 64;

/// Canonical store-process metric names, pre-registered by the server at
/// boot ([`register_store_metrics`]) so every scrape and flight-recorder
/// line carries the full schema even before the first event.
/// `(name, kind)` with kind `c`ounter / `g`auge / `h`istogram.
pub const STORE_METRICS: &[(&str, char)] = &[
    ("server.tick_ns", 'h'),
    ("server.evictions", 'c'),
    ("server.protocol_errors", 'c'),
    ("journal.fsync_ns", 'h'),
    ("journal.bytes", 'c'),
    ("compact.duration_ns", 'h'),
    ("compact.floor", 'g'),
    ("client.reconnects", 'c'),
    ("client.protocol_errors", 'c'),
    ("pool.coalesced_fetches", 'c'),
    ("proposal.absorb_ns", 'h'),
    ("proposal.ess", 'g'),
    ("peer.cursor_lag", 'g'),
    ("fault.injected_errors", 'c'),
    ("fault.withheld_params", 'c'),
    ("fault.withheld_deltas", 'c'),
    ("fault.partial_deltas", 'c'),
];

// ---------------------------------------------------------------------------
// metric kinds
// ---------------------------------------------------------------------------

/// Monotone counter.  All ops are relaxed atomics: per-metric totals are
/// exact, cross-metric consistency is best-effort (see `snapshot`).
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Last-written `f64` value (stored as bits in an atomic).
#[derive(Debug, Default)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Fixed log2-bucket histogram: recording is 4 relaxed atomic ops and no
/// allocation; quantiles are derived from the buckets at snapshot time.
#[derive(Debug)]
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
    buckets: [AtomicU64; HIST_BUCKETS],
}

impl Histogram {
    fn new() -> Histogram {
        Histogram {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Record one observation (typically nanoseconds).
    pub fn record(&self, v: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
    }

    /// Record the elapsed time of `sw` in nanoseconds.
    pub fn record_elapsed(&self, sw: &Stopwatch) {
        self.record(sw.elapsed_ns());
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }
}

/// Bucket index of a value: its bit length, clamped into the last bucket.
fn bucket_of(v: u64) -> usize {
    ((64 - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
}

/// Inclusive upper bound of bucket `i` (what quantile estimates report).
fn bucket_upper(i: usize) -> u64 {
    if i >= HIST_BUCKETS - 1 {
        u64::MAX
    } else if i == 0 {
        0
    } else {
        (1u64 << i) - 1
    }
}

// ---------------------------------------------------------------------------
// timing without leaking Instant::now to call sites
// ---------------------------------------------------------------------------

/// A started wall-clock timer (see [`start`]).
pub struct Stopwatch {
    t0: Instant,
}

impl Stopwatch {
    pub fn elapsed_ns(&self) -> u64 {
        self.t0.elapsed().as_nanos().min(u64::MAX as u128) as u64
    }
}

/// Start a stopwatch for a latency histogram.  Lives here (not at the
/// instrumentation site) so the determinism lint's wallclock waiver stays
/// confined to this module.
pub fn start() -> Stopwatch {
    Stopwatch { t0: Instant::now() }
}

// ---------------------------------------------------------------------------
// registry
// ---------------------------------------------------------------------------

enum Metric {
    Counter(&'static Counter),
    Gauge(&'static Gauge),
    Histogram(&'static Histogram),
}

fn registry() -> std::sync::MutexGuard<'static, BTreeMap<String, Metric>> {
    static REGISTRY: OnceLock<Mutex<BTreeMap<String, Metric>>> = OnceLock::new();
    // Poison-tolerant: the only panics under this lock are the kind-mismatch
    // panics below, which never leave the map half-updated, so a poisoned
    // guard is still safe to use (and tests exercise the panic path).
    REGISTRY
        .get_or_init(|| Mutex::new(BTreeMap::new()))
        .lock()
        .unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Counter handle for `name`, registering on first use.  Leaks one
/// allocation per distinct name — bounded by the metric namespace.
/// Panics if `name` is already registered as a different kind
/// (programmer error, caught by any test touching the site).
pub fn counter(name: &str) -> &'static Counter {
    let mut reg = registry();
    let entry = reg.entry(name.to_string());
    match entry.or_insert_with(|| Metric::Counter(Box::leak(Box::default()))) {
        Metric::Counter(c) => c,
        // analyze: allow(panics): kind mismatch is a programmer error the telemetry lint rejects statically
        _ => panic!("telemetry metric {name:?} is not a counter"),
    }
}

/// Gauge handle for `name` (see [`counter`] for registry semantics).
pub fn gauge(name: &str) -> &'static Gauge {
    let mut reg = registry();
    let entry = reg.entry(name.to_string());
    match entry.or_insert_with(|| Metric::Gauge(Box::leak(Box::default()))) {
        Metric::Gauge(g) => g,
        // analyze: allow(panics): kind mismatch is a programmer error the telemetry lint rejects statically
        _ => panic!("telemetry metric {name:?} is not a gauge"),
    }
}

/// Histogram handle for `name` (see [`counter`] for registry semantics).
pub fn histogram(name: &str) -> &'static Histogram {
    let mut reg = registry();
    let entry = reg.entry(name.to_string());
    match entry.or_insert_with(|| Metric::Histogram(Box::leak(Box::new(Histogram::new())))) {
        Metric::Histogram(h) => h,
        // analyze: allow(panics): kind mismatch is a programmer error the telemetry lint rejects statically
        _ => panic!("telemetry metric {name:?} is not a histogram"),
    }
}

/// Pre-register the canonical store-process metrics ([`STORE_METRICS`])
/// so scrapes expose the full schema from boot.  Idempotent.
pub fn register_store_metrics() {
    for &(name, kind) in STORE_METRICS {
        match kind {
            'c' => {
                counter(name);
            }
            'g' => {
                gauge(name);
            }
            _ => {
                histogram(name);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// snapshots
// ---------------------------------------------------------------------------

/// Point-in-time copy of one histogram.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum: u64,
    /// Exact maximum observed value (0 when empty).
    pub max: u64,
    /// Sparse `(bucket index, count)` pairs, ascending by index.
    pub buckets: Vec<(u8, u64)>,
}

impl HistogramSnapshot {
    /// Upper-bound quantile estimate from the log2 buckets (`q` in 0..=1).
    /// The top bucket reports the exact `max` instead of `u64::MAX`.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for &(i, c) in &self.buckets {
            seen += c;
            if seen >= rank {
                return bucket_upper(i as usize).min(self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.5)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }
}

/// Point-in-time copy of the whole registry.
///
/// Snapshot consistency: each metric is internally coherent (a counter is
/// one atomic read; a histogram's `count` is read before its buckets so
/// per-bucket sums can only trail, never exceed, concurrent recording),
/// and successive snapshots are monotone per counter/histogram.  Cross-
/// metric alignment is best-effort — there is no global stop-the-world.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Snapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub histograms: BTreeMap<String, HistogramSnapshot>,
}

/// Snapshot the registry (see [`Snapshot`] for consistency guarantees).
pub fn snapshot() -> Snapshot {
    let reg = registry();
    let mut snap = Snapshot::default();
    for (name, metric) in reg.iter() {
        match metric {
            Metric::Counter(c) => {
                snap.counters.insert(name.clone(), c.get());
            }
            Metric::Gauge(g) => {
                snap.gauges.insert(name.clone(), g.get());
            }
            Metric::Histogram(h) => {
                // Concurrent records bump count before buckets, so the
                // bucket counts read below may trail or lead this value;
                // `quantile` tolerates both (ranks past the bucket sum
                // fall back to the exact max).
                let count = h.count.load(Ordering::Relaxed);
                let sum = h.sum.load(Ordering::Relaxed);
                let max = h.max.load(Ordering::Relaxed);
                let mut buckets = Vec::new();
                for (i, b) in h.buckets.iter().enumerate() {
                    let c = b.load(Ordering::Relaxed);
                    if c > 0 {
                        buckets.push((i as u8, c));
                    }
                }
                snap.histograms.insert(
                    name.clone(),
                    HistogramSnapshot {
                        count,
                        sum,
                        max,
                        buckets,
                    },
                );
            }
        }
    }
    snap
}

impl Snapshot {
    /// Canonical machine format: the `FetchMetrics` payload and the
    /// flight-recorder line.  `p50`/`p99` are included for human readers
    /// but re-derived from the buckets on parse.
    pub fn to_json(&self) -> Json {
        let mut counters = BTreeMap::new();
        for (k, &v) in &self.counters {
            counters.insert(k.clone(), Json::Num(v as f64));
        }
        let mut gauges = BTreeMap::new();
        for (k, &v) in &self.gauges {
            gauges.insert(k.clone(), Json::Num(v));
        }
        let mut histograms = BTreeMap::new();
        for (k, h) in &self.histograms {
            let mut buckets = Vec::new();
            for &(i, c) in &h.buckets {
                buckets.push(Json::Arr(vec![Json::Num(i as f64), Json::Num(c as f64)]));
            }
            let obj = Json::obj(vec![
                ("count", Json::Num(h.count as f64)),
                ("sum", Json::Num(h.sum as f64)),
                ("max", Json::Num(h.max as f64)),
                ("p50", Json::Num(h.p50() as f64)),
                ("p99", Json::Num(h.p99() as f64)),
                ("buckets", Json::Arr(buckets)),
            ]);
            histograms.insert(k.clone(), obj);
        }
        Json::obj(vec![
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("histograms", Json::Obj(histograms)),
        ])
    }

    /// Parse a snapshot back from [`Snapshot::to_json`] output (the
    /// `issgd metrics` client does this to render the exposition).
    pub fn from_json(j: &Json) -> Result<Snapshot> {
        fn section<'a>(j: &'a Json, key: &str) -> Result<&'a BTreeMap<String, Json>> {
            let sec = j.get(key).and_then(Json::as_obj);
            sec.with_context(|| format!("snapshot missing {key:?}"))
        }
        let mut snap = Snapshot::default();
        for (k, v) in section(j, "counters")? {
            let v = v.as_f64().with_context(|| format!("counter {k:?}"))?;
            snap.counters.insert(k.clone(), v as u64);
        }
        for (k, v) in section(j, "gauges")? {
            let v = v.as_f64().with_context(|| format!("gauge {k:?}"))?;
            snap.gauges.insert(k.clone(), v);
        }
        for (k, v) in section(j, "histograms")? {
            let mut buckets = Vec::new();
            for pair in v.req_arr("buckets")? {
                let pair = pair.as_arr().context("histogram bucket not a pair")?;
                anyhow::ensure!(pair.len() == 2, "histogram bucket not a pair");
                let i = pair[0].as_usize().context("bucket index not numeric")?;
                anyhow::ensure!(i < HIST_BUCKETS, "bucket index {i} out of range");
                let c = pair[1].as_f64().context("bucket count not numeric")? as u64;
                buckets.push((i as u8, c));
            }
            snap.histograms.insert(
                k.clone(),
                HistogramSnapshot {
                    count: v.req_f64("count")? as u64,
                    sum: v.req_f64("sum")? as u64,
                    max: v.req_f64("max")? as u64,
                    buckets,
                },
            );
        }
        Ok(snap)
    }

    /// Parse from serialized JSON text (the `FetchMetrics` payload).
    pub fn from_json_str(text: &str) -> Result<Snapshot> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("snapshot JSON: {e}"))?;
        Snapshot::from_json(&j)
    }

    /// Prometheus-style text exposition: counters and gauges as single
    /// samples, histograms as summaries with `quantile` labels plus
    /// `_sum`/`_count`/`_max` samples.  Names are prefixed `issgd_` and
    /// dots become underscores.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, &v) in &self.counters {
            let n = prom_name(name);
            out.push_str(&format!("# TYPE {n} counter\n{n} {v}\n"));
        }
        for (name, &v) in &self.gauges {
            let n = prom_name(name);
            out.push_str(&format!("# TYPE {n} gauge\n{n} {v}\n"));
        }
        for (name, h) in &self.histograms {
            let n = prom_name(name);
            out.push_str(&format!("# TYPE {n} summary\n"));
            out.push_str(&format!("{n}{{quantile=\"0.5\"}} {}\n", h.p50()));
            out.push_str(&format!("{n}{{quantile=\"0.99\"}} {}\n", h.p99()));
            out.push_str(&format!("{n}_sum {}\n", h.sum));
            out.push_str(&format!("{n}_count {}\n", h.count));
            out.push_str(&format!("{n}_max {}\n", h.max));
        }
        out
    }
}

fn prom_name(name: &str) -> String {
    let mut out = String::from("issgd_");
    for ch in name.chars() {
        if ch.is_ascii_alphanumeric() {
            out.push(ch);
        } else {
            out.push('_');
        }
    }
    out
}

// ---------------------------------------------------------------------------
// flight recorder
// ---------------------------------------------------------------------------

/// Periodic JSONL dump of the registry (`db-server --telemetry-dump`):
/// one [`Snapshot::to_json`] line per interval, appended so chaos runs
/// can be reconstructed post-hoc.  Drive it by calling [`Dumper::tick`]
/// from a loop; it no-ops until the interval has elapsed and disables
/// itself (with one warning) on a write error.
pub struct Dumper {
    path: PathBuf,
    every: Duration,
    last: Option<Instant>,
    dead: bool,
}

impl Dumper {
    pub fn new(path: &Path, every: Duration) -> Dumper {
        Dumper {
            path: path.to_path_buf(),
            every,
            last: None,
            dead: false,
        }
    }

    /// Append one snapshot line if the interval has elapsed.
    pub fn tick(&mut self) {
        if self.dead || self.last.is_some_and(|t| t.elapsed() < self.every) {
            return;
        }
        self.last = Some(Instant::now());
        if let Err(e) = self.append_line() {
            crate::log_warn!(
                "telemetry",
                "disabling --telemetry-dump, could not write {}: {e}",
                self.path.display()
            );
            self.dead = true;
        }
    }

    fn append_line(&self) -> Result<()> {
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(&self.path)?;
        writeln!(f, "{}", snapshot().to_json().to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
        assert_eq!(bucket_upper(0), 0);
        assert_eq!(bucket_upper(2), 3);
        assert_eq!(bucket_upper(10), 1023);
        assert_eq!(bucket_upper(HIST_BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn histogram_quantiles_are_upper_bounds() {
        let h = histogram("test.unit.quantiles");
        for v in [1u64, 2, 3, 100, 1000, 1_000_000] {
            h.record(v);
        }
        let snap = snapshot();
        let hs = &snap.histograms["test.unit.quantiles"];
        assert_eq!(hs.count, 6);
        assert_eq!(hs.sum, 1 + 2 + 3 + 100 + 1000 + 1_000_000);
        assert_eq!(hs.max, 1_000_000);
        // p50 falls in the bucket holding 3 (values 2..=3).
        assert_eq!(hs.p50(), 3);
        // p99 lands in the last bucket, capped at the exact max.
        assert_eq!(hs.p99(), 1_000_000);
        assert!(hs.quantile(0.0) >= 1);
        assert_eq!(hs.quantile(1.0), 1_000_000);
    }

    #[test]
    fn empty_histogram_quantiles_are_zero() {
        let h = histogram("test.unit.empty");
        let _ = h; // registered but never recorded
        let snap = snapshot();
        let hs = &snap.histograms["test.unit.empty"];
        assert_eq!(hs.count, 0);
        assert_eq!(hs.p50(), 0);
        assert_eq!(hs.p99(), 0);
    }

    #[test]
    fn counter_and_gauge_roundtrip() {
        let c = counter("test.unit.counter");
        let g = gauge("test.unit.gauge");
        c.add(41);
        c.inc();
        g.set(0.75);
        let snap = snapshot();
        assert!(snap.counters["test.unit.counter"] >= 42);
        assert_eq!(snap.gauges["test.unit.gauge"], 0.75);
        // Same name returns the same handle.
        assert_eq!(counter("test.unit.counter").get(), c.get());
    }

    #[test]
    #[should_panic(expected = "not a gauge")]
    fn kind_mismatch_panics() {
        counter("test.unit.mismatch");
        gauge("test.unit.mismatch");
    }

    #[test]
    fn json_roundtrip_and_prometheus_render() {
        counter("test.unit.json_c").add(7);
        gauge("test.unit.json_g").set(0.5);
        let h = histogram("test.unit.json_h");
        h.record(5);
        h.record(900);
        let snap = snapshot();
        let text = snap.to_json().to_string();
        let back = Snapshot::from_json_str(&text).unwrap();
        assert_eq!(back.counters["test.unit.json_c"], snap.counters["test.unit.json_c"]);
        assert_eq!(back.gauges["test.unit.json_g"], 0.5);
        assert_eq!(back.histograms["test.unit.json_h"], snap.histograms["test.unit.json_h"]);
        let prom = snap.to_prometheus();
        assert!(prom.contains("# TYPE issgd_test_unit_json_c counter"));
        assert!(prom.contains("issgd_test_unit_json_c 7"));
        assert!(prom.contains("# TYPE issgd_test_unit_json_h summary"));
        assert!(prom.contains("issgd_test_unit_json_h{quantile=\"0.99\"}"));
        assert!(prom.contains("issgd_test_unit_json_h_count 2"));
    }

    #[test]
    fn store_metrics_preregister_idempotently() {
        register_store_metrics();
        register_store_metrics();
        let snap = snapshot();
        for &(name, kind) in STORE_METRICS {
            let present = match kind {
                'c' => snap.counters.contains_key(name),
                'g' => snap.gauges.contains_key(name),
                _ => snap.histograms.contains_key(name),
            };
            assert!(present, "{name} missing after register_store_metrics");
        }
    }

    #[test]
    fn dumper_appends_parseable_lines() {
        let path = std::env::temp_dir().join(format!("issgd-telem-{}", std::process::id()));
        let _ = std::fs::remove_file(&path);
        counter("test.unit.dumped").inc();
        let mut d = Dumper::new(&path, Duration::from_millis(1));
        d.tick(); // first tick dumps immediately
        std::thread::sleep(Duration::from_millis(3));
        d.tick();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            let snap = Snapshot::from_json_str(line).unwrap();
            assert!(snap.counters["test.unit.dumped"] >= 1);
        }
        let _ = std::fs::remove_file(&path);
    }
}
