//! Baselines the paper compares against.
//!
//! *Regular SGD* shares the entire stack with ISSGD — same `train_step`
//! artifact, same master loop — differing only in the proposal (uniform)
//! and coefficients (all ones).  That is exactly the paper's comparison
//! protocol: in their SGD runs a background worker still computes
//! statistics, but the minibatch distribution is uniform.

use anyhow::Result;

use crate::config::{RunConfig, TrainerKind};
use crate::coordinator::{run_sim_with_engine, SimOutcome};
use crate::runtime::Engine;

/// Convert any run config into its uniform-SGD twin (same seed, same
/// schedule, same data) — the controlled comparison of figures 2–3.
pub fn sgd_twin(cfg: &RunConfig) -> RunConfig {
    RunConfig {
        trainer: TrainerKind::UniformSgd,
        ..cfg.clone()
    }
}

/// Run the uniform-SGD baseline for `cfg` (ignoring its trainer field).
pub fn run_sgd_baseline(cfg: &RunConfig, engine: &Engine) -> Result<SimOutcome> {
    run_sim_with_engine(&sgd_twin(cfg), engine)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twin_flips_trainer_only() {
        let cfg = RunConfig::setting_b();
        let twin = sgd_twin(&cfg);
        assert_eq!(twin.trainer, TrainerKind::UniformSgd);
        assert_eq!(twin.lr, cfg.lr);
        assert_eq!(twin.seed, cfg.seed);
        assert_eq!(twin.steps, cfg.steps);
    }
}
