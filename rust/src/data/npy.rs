//! Minimal NumPy `.npy` reader/writer — the bridge to *real* data.
//!
//! The synthetic dataset (DESIGN.md §3) stands in for SVHN in this
//! environment, but the system is built for the real thing: export SVHN
//! with numpy (`np.save("features.npy", X.astype(np.float32))`,
//! `np.save("labels.npy", y.astype(np.int64))`) and load it with
//! [`NpyDataset::load`] — no python on the training path, so the loader
//! is implemented here (format spec:
//! https://numpy.org/doc/stable/reference/generated/numpy.lib.format.html).
//!
//! Supports format versions 1.0/2.0, C-order, little-endian `f32`/`f64`
//! (features) and `u8`/`i32`/`i64` (labels) — the dtypes numpy actually
//! emits for image data and class labels.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Dataset;

/// A parsed `.npy` array (flat data + shape).
#[derive(Debug, Clone, PartialEq)]
pub struct NpyArray {
    pub shape: Vec<usize>,
    pub data: NpyData,
}

#[derive(Debug, Clone, PartialEq)]
pub enum NpyData {
    F32(Vec<f32>),
    F64(Vec<f64>),
    U8(Vec<u8>),
    I32(Vec<i32>),
    I64(Vec<i64>),
}

impl NpyArray {
    pub fn len(&self) -> usize {
        match &self.data {
            NpyData::F32(v) => v.len(),
            NpyData::F64(v) => v.len(),
            NpyData::U8(v) => v.len(),
            NpyData::I32(v) => v.len(),
            NpyData::I64(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Convert to f32 (lossy for i64 > 2^24, fine for labels/pixels).
    pub fn to_f32(&self) -> Vec<f32> {
        match &self.data {
            NpyData::F32(v) => v.clone(),
            NpyData::F64(v) => v.iter().map(|&x| x as f32).collect(),
            NpyData::U8(v) => v.iter().map(|&x| x as f32).collect(),
            NpyData::I32(v) => v.iter().map(|&x| x as f32).collect(),
            NpyData::I64(v) => v.iter().map(|&x| x as f32).collect(),
        }
    }

    /// Convert to u32 labels; errors on negatives or non-integers.
    pub fn to_labels(&self) -> Result<Vec<u32>> {
        let check = |x: f64, i: usize| -> Result<u32> {
            anyhow::ensure!(
                x >= 0.0 && x.fract() == 0.0 && x < u32::MAX as f64,
                "label {x} at index {i} is not a small non-negative integer"
            );
            Ok(x as u32)
        };
        match &self.data {
            NpyData::U8(v) => Ok(v.iter().map(|&x| x as u32).collect()),
            NpyData::I32(v) => v
                .iter()
                .enumerate()
                .map(|(i, &x)| check(x as f64, i))
                .collect(),
            NpyData::I64(v) => v
                .iter()
                .enumerate()
                .map(|(i, &x)| check(x as f64, i))
                .collect(),
            NpyData::F32(v) => v
                .iter()
                .enumerate()
                .map(|(i, &x)| check(x as f64, i))
                .collect(),
            NpyData::F64(v) => v.iter().enumerate().map(|(i, &x)| check(x, i)).collect(),
        }
    }
}

/// Read a `.npy` file.
pub fn read_npy(path: &Path) -> Result<NpyArray> {
    let mut f = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    anyhow::ensure!(&magic[..6] == b"\x93NUMPY", "not a .npy file (bad magic)");
    let (major, _minor) = (magic[6], magic[7]);
    let header_len = match major {
        1 => {
            let mut b = [0u8; 2];
            f.read_exact(&mut b)?;
            u16::from_le_bytes(b) as usize
        }
        2 => {
            let mut b = [0u8; 4];
            f.read_exact(&mut b)?;
            u32::from_le_bytes(b) as usize
        }
        v => bail!("unsupported .npy format version {v}"),
    };
    let mut header = vec![0u8; header_len];
    f.read_exact(&mut header)?;
    let header = String::from_utf8(header).context("non-utf8 .npy header")?;
    let (descr, fortran, shape) = parse_header(&header)?;
    anyhow::ensure!(!fortran, "fortran_order arrays not supported");
    let count: usize = shape.iter().product();

    let mut raw = Vec::new();
    f.read_to_end(&mut raw)?;
    let need = |elem: usize| -> Result<()> {
        anyhow::ensure!(
            raw.len() >= count * elem,
            "file truncated: {} bytes for {count} x {elem}B elements",
            raw.len()
        );
        Ok(())
    };
    let data = match descr.as_str() {
        "<f4" | "|f4" => {
            need(4)?;
            NpyData::F32(
                raw[..count * 4]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            )
        }
        "<f8" => {
            need(8)?;
            NpyData::F64(
                raw[..count * 8]
                    .chunks_exact(8)
                    .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            )
        }
        "|u1" => {
            need(1)?;
            NpyData::U8(raw[..count].to_vec())
        }
        "<i4" => {
            need(4)?;
            NpyData::I32(
                raw[..count * 4]
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            )
        }
        "<i8" => {
            need(8)?;
            NpyData::I64(
                raw[..count * 8]
                    .chunks_exact(8)
                    .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            )
        }
        other => bail!("unsupported dtype descr {other:?}"),
    };
    Ok(NpyArray { shape, data })
}

/// Write a `.npy` (format 1.0, C-order, little-endian).
pub fn write_npy(path: &Path, array: &NpyArray) -> Result<()> {
    let descr = match &array.data {
        NpyData::F32(_) => "<f4",
        NpyData::F64(_) => "<f8",
        NpyData::U8(_) => "|u1",
        NpyData::I32(_) => "<i4",
        NpyData::I64(_) => "<i8",
    };
    let count: usize = array.shape.iter().product();
    anyhow::ensure!(count == array.len(), "shape/data mismatch");
    let shape_str = match array.shape.len() {
        1 => format!("({},)", array.shape[0]),
        _ => format!(
            "({})",
            array
                .shape
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '{descr}', 'fortran_order': False, 'shape': {shape_str}, }}"
    );
    // Pad so that magic(6)+version(2)+len(2)+header is a multiple of 64.
    let unpadded = 10 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');

    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(b"\x93NUMPY\x01\x00")?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    match &array.data {
        NpyData::F32(v) => {
            for x in v {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        NpyData::F64(v) => {
            for x in v {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        NpyData::U8(v) => f.write_all(v)?,
        NpyData::I32(v) => {
            for x in v {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        NpyData::I64(v) => {
            for x in v {
                f.write_all(&x.to_le_bytes())?;
            }
        }
    }
    Ok(())
}

fn parse_header(header: &str) -> Result<(String, bool, Vec<usize>)> {
    // The header is a python dict literal with a known key set; a tiny
    // hand parser beats dragging in a python-literal grammar.
    let grab = |key: &str| -> Result<&str> {
        let pat = format!("'{key}':");
        let at = header.find(&pat).with_context(|| format!("missing {key}"))?;
        Ok(header[at + pat.len()..].trim_start())
    };
    let descr_part = grab("descr")?;
    anyhow::ensure!(descr_part.starts_with('\''), "bad descr");
    let descr = descr_part[1..]
        .split('\'')
        .next()
        .context("bad descr")?
        .to_string();
    let fortran = grab("fortran_order")?.starts_with("True");
    let shape_part = grab("shape")?;
    anyhow::ensure!(shape_part.starts_with('('), "bad shape");
    let close = shape_part.find(')').context("bad shape")?;
    let inner = &shape_part[1..close];
    let shape: Vec<usize> = inner
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| s.parse::<usize>().context("bad shape dim"))
        .collect::<Result<_>>()?;
    Ok((descr, fortran, shape))
}

/// A dataset loaded from `features.npy` (N×D f32) + `labels.npy` (N ints).
pub struct NpyDataset {
    features: Vec<f32>,
    labels: Vec<u32>,
    dim: usize,
    n_classes: usize,
}

impl NpyDataset {
    /// Load and validate a features/labels pair.  `n_classes` of 0 means
    /// infer as `max(label) + 1`.
    pub fn load(features_path: &Path, labels_path: &Path, n_classes: usize) -> Result<NpyDataset> {
        let feats = read_npy(features_path)?;
        anyhow::ensure!(
            feats.shape.len() == 2,
            "features must be 2-d (N, D), got {:?}",
            feats.shape
        );
        let (n, dim) = (feats.shape[0], feats.shape[1]);
        let labels_arr = read_npy(labels_path)?;
        let labels = labels_arr.to_labels()?;
        anyhow::ensure!(
            labels.len() == n,
            "{n} feature rows but {} labels",
            labels.len()
        );
        let max_label = labels.iter().copied().max().unwrap_or(0);
        let n_classes = if n_classes == 0 {
            max_label as usize + 1
        } else {
            anyhow::ensure!(
                (max_label as usize) < n_classes,
                "label {max_label} out of range for {n_classes} classes"
            );
            n_classes
        };
        Ok(NpyDataset {
            features: feats.to_f32(),
            labels,
            dim,
            n_classes,
        })
    }
}

impl Dataset for NpyDataset {
    fn len(&self) -> usize {
        self.labels.len()
    }
    fn dim(&self) -> usize {
        self.dim
    }
    fn n_classes(&self) -> usize {
        self.n_classes
    }
    fn features(&self, idx: usize) -> &[f32] {
        &self.features[idx * self.dim..(idx + 1) * self.dim]
    }
    fn label(&self, idx: usize) -> u32 {
        self.labels[idx]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("issgd-npy-{}-{name}", std::process::id()))
    }

    #[test]
    fn f32_roundtrip() {
        let arr = NpyArray {
            shape: vec![2, 3],
            data: NpyData::F32(vec![1.0, -2.5, 3.25, 0.0, 1e-7, 1e7]),
        };
        let p = tmp("f32.npy");
        write_npy(&p, &arr).unwrap();
        assert_eq!(read_npy(&p).unwrap(), arr);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn label_dtypes_roundtrip() {
        for data in [
            NpyData::U8(vec![0, 1, 9]),
            NpyData::I32(vec![0, 1, 9]),
            NpyData::I64(vec![0, 1, 9]),
        ] {
            let arr = NpyArray {
                shape: vec![3],
                data,
            };
            let p = tmp("labels.npy");
            write_npy(&p, &arr).unwrap();
            let back = read_npy(&p).unwrap();
            assert_eq!(back.to_labels().unwrap(), vec![0, 1, 9]);
            std::fs::remove_file(&p).ok();
        }
    }

    #[test]
    fn header_is_numpy_compatible_shape() {
        // 1-element tuple must keep the trailing comma: "(3,)".
        let arr = NpyArray {
            shape: vec![3],
            data: NpyData::U8(vec![1, 2, 3]),
        };
        let p = tmp("one-d.npy");
        write_npy(&p, &arr).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        let header = String::from_utf8_lossy(&bytes[10..bytes.len() - 3]);
        assert!(header.contains("(3,)"), "header: {header}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("garbage.npy");
        std::fs::write(&p, b"not numpy at all").unwrap();
        assert!(read_npy(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_bad_labels() {
        let arr = NpyArray {
            shape: vec![2],
            data: NpyData::F32(vec![1.5, 2.0]),
        };
        assert!(arr.to_labels().is_err());
        let neg = NpyArray {
            shape: vec![1],
            data: NpyData::I64(vec![-3]),
        };
        assert!(neg.to_labels().is_err());
    }

    #[test]
    fn dataset_load_and_validate() {
        let fp = tmp("ds-features.npy");
        let lp = tmp("ds-labels.npy");
        write_npy(
            &fp,
            &NpyArray {
                shape: vec![4, 3],
                data: NpyData::F32((0..12).map(|i| i as f32).collect()),
            },
        )
        .unwrap();
        write_npy(
            &lp,
            &NpyArray {
                shape: vec![4],
                data: NpyData::I64(vec![0, 2, 1, 2]),
            },
        )
        .unwrap();
        let ds = NpyDataset::load(&fp, &lp, 0).unwrap();
        assert_eq!(ds.len(), 4);
        assert_eq!(ds.dim(), 3);
        assert_eq!(ds.n_classes(), 3); // inferred max+1
        assert_eq!(ds.features(1), &[3.0, 4.0, 5.0]);
        assert_eq!(ds.label(3), 2);
        // explicit class count must bound labels
        assert!(NpyDataset::load(&fp, &lp, 2).is_err());
        std::fs::remove_file(&fp).ok();
        std::fs::remove_file(&lp).ok();
    }

    #[test]
    fn mismatched_lengths_rejected() {
        let fp = tmp("mm-features.npy");
        let lp = tmp("mm-labels.npy");
        write_npy(
            &fp,
            &NpyArray {
                shape: vec![2, 2],
                data: NpyData::F32(vec![0.0; 4]),
            },
        )
        .unwrap();
        write_npy(
            &lp,
            &NpyArray {
                shape: vec![3],
                data: NpyData::U8(vec![0, 1, 0]),
            },
        )
        .unwrap();
        assert!(NpyDataset::load(&fp, &lp, 0).is_err());
        std::fs::remove_file(&fp).ok();
        std::fs::remove_file(&lp).ok();
    }
}
