//! Batch assembly: gather dataset rows into the dense row-major buffers +
//! one-hot label matrices the AOT entry points expect.
//!
//! The HLO artifacts are shape-specialised, so every batch has a fixed
//! size; when fewer than `batch` real examples are available the builder
//! pads by repeating rows and reports the effective count so aggregate
//! statistics (loss sums, correct counts) can be corrected by the caller.

use super::Dataset;

/// Reusable staging buffers for one batch shape.  Reuse avoids
/// re-allocating `batch*dim` floats on the master's hot loop.
pub struct BatchBuilder {
    batch: usize,
    dim: usize,
    n_classes: usize,
    pub x: Vec<f32>,
    pub y: Vec<f32>,
}

impl BatchBuilder {
    pub fn new(batch: usize, dim: usize, n_classes: usize) -> Self {
        BatchBuilder {
            batch,
            dim,
            n_classes,
            x: vec![0.0; batch * dim],
            y: vec![0.0; batch * n_classes],
        }
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Fill the staging buffers from `dataset` rows `indices`.
    ///
    /// Returns the number of *real* (un-padded) examples.  Panics if
    /// `indices` is empty or longer than the batch size — both are caller
    /// bugs, not data conditions.
    pub fn fill<D: Dataset + ?Sized>(&mut self, dataset: &D, indices: &[usize]) -> usize {
        assert!(!indices.is_empty(), "empty batch");
        assert!(
            indices.len() <= self.batch,
            "{} indices for batch size {}",
            indices.len(),
            self.batch
        );
        assert_eq!(dataset.dim(), self.dim, "dataset dim mismatch");
        self.y.fill(0.0);
        for slot in 0..self.batch {
            // Pad by cycling through the provided indices: padded rows are
            // *valid* examples, so the executable never sees garbage, and
            // the caller discards their contribution via the real count.
            let idx = indices[slot % indices.len()];
            let row = dataset.features(idx);
            self.x[slot * self.dim..(slot + 1) * self.dim].copy_from_slice(row);
            let label = dataset.label(idx) as usize;
            debug_assert!(label < self.n_classes);
            self.y[slot * self.n_classes + label] = 1.0;
        }
        indices.len()
    }

    /// Fill and also produce the per-slot loss coefficient vector used by
    /// `train_step`: `coef[m] = scale / omega[indices[m]]` for real rows and
    /// `0` for padded rows (padding then contributes nothing to loss or
    /// gradient — exactness, not approximation).
    pub fn fill_weighted<D: Dataset + ?Sized>(
        &mut self,
        dataset: &D,
        indices: &[usize],
        coef_of: impl Fn(usize) -> f32,
        coef_out: &mut Vec<f32>,
    ) -> usize {
        let real = self.fill(dataset, indices);
        coef_out.clear();
        coef_out.resize(self.batch, 0.0);
        for slot in 0..real.min(self.batch) {
            coef_out[slot] = coef_of(indices[slot]);
        }
        real
    }
}

/// Iterate index chunks of size `batch` over `[0, n)` (last chunk short).
pub fn chunks(n: usize, batch: usize) -> impl Iterator<Item = Vec<usize>> {
    (0..n.div_ceil(batch)).map(move |c| {
        let start = c * batch;
        (start..(start + batch).min(n)).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{SynthDataset, SynthSpec};

    fn data() -> SynthDataset {
        SynthDataset::generate(1, SynthSpec::tiny(50))
    }

    #[test]
    fn fills_rows_and_onehot() {
        let d = data();
        let mut b = BatchBuilder::new(4, 64, 10);
        let real = b.fill(&d, &[3, 7, 9, 11]);
        assert_eq!(real, 4);
        assert_eq!(&b.x[0..64], d.features(3));
        assert_eq!(&b.x[2 * 64..3 * 64], d.features(9));
        for slot in 0..4 {
            let row = &b.y[slot * 10..(slot + 1) * 10];
            assert_eq!(row.iter().sum::<f32>(), 1.0);
            let hot = row.iter().position(|&v| v == 1.0).unwrap();
            assert_eq!(hot as u32, d.label([3, 7, 9, 11][slot]));
        }
    }

    #[test]
    fn pads_by_cycling() {
        let d = data();
        let mut b = BatchBuilder::new(5, 64, 10);
        let real = b.fill(&d, &[2, 4]);
        assert_eq!(real, 2);
        assert_eq!(&b.x[2 * 64..3 * 64], d.features(2)); // slot 2 cycles to idx 0
        assert_eq!(&b.x[3 * 64..4 * 64], d.features(4));
    }

    #[test]
    fn weighted_fill_zeroes_padding() {
        let d = data();
        let mut b = BatchBuilder::new(4, 64, 10);
        let mut coef = Vec::new();
        let real = b.fill_weighted(&d, &[1, 2], |i| (i + 1) as f32, &mut coef);
        assert_eq!(real, 2);
        assert_eq!(coef, vec![2.0, 3.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "empty batch")]
    fn empty_batch_panics() {
        let d = data();
        BatchBuilder::new(4, 64, 10).fill(&d, &[]);
    }

    #[test]
    fn chunk_iteration_covers() {
        let cs: Vec<Vec<usize>> = chunks(10, 4).collect();
        assert_eq!(cs.len(), 3);
        assert_eq!(cs[2], vec![8, 9]);
        assert_eq!(cs.concat(), (0..10).collect::<Vec<_>>());
    }
}
