//! Synthetic SVHN-like dataset (the paper's data substitution, DESIGN.md §3).
//!
//! Generative model, per example `i` with class `c ~ U(10)`:
//!
//!   x_i = mu_c + sigma_tier * eps,    eps ~ N(0, I_d)
//!
//! where the class prototypes `mu_c` are fixed Gaussian directions and the
//! noise scale `sigma_tier` depends on a per-example **difficulty tier**:
//! most examples are easy (low noise, quickly fit, small gradients), a
//! minority are hard (high noise + occasional label flips, persistently
//! large gradients).  That minority is exactly what makes the paper's
//! importance sampling pay off: the per-example gradient-norm distribution
//! becomes heavy-tailed, so ``q* ∝ ||g||`` concentrates updates on the
//! informative tail, while for a uniform-difficulty dataset ISSGD
//! degenerates towards plain SGD.
//!
//! Everything is a deterministic function of `(seed, spec)`; each example
//! is generated from its own PCG stream so any subset can be materialised
//! independently (workers materialise only their shard).

use super::Dataset;
use crate::util::rng::Pcg64;

/// Difficulty tier of an example.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Difficulty {
    Easy,
    Hard,
}

/// Generation parameters.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    /// Number of examples.
    pub n: usize,
    /// Feature dimensionality (paper: 3072 = 32*32*3).
    pub dim: usize,
    /// Number of classes (paper: 10 digits).
    pub n_classes: usize,
    /// Norm of each class prototype.
    pub proto_scale: f32,
    /// Noise std for easy examples.
    pub easy_noise: f32,
    /// Noise std for hard examples.
    pub hard_noise: f32,
    /// Fraction of hard examples.
    pub hard_frac: f64,
    /// Probability a hard example's label is resampled uniformly.
    pub label_noise: f64,
}

impl SynthSpec {
    /// Shape-compatible with the `small`/`paper` model configs.
    pub fn svhn_like(n: usize) -> Self {
        SynthSpec {
            n,
            dim: 3072,
            n_classes: 10,
            proto_scale: 1.0,
            easy_noise: 0.35,
            hard_noise: 1.3,
            hard_frac: 0.2,
            label_noise: 0.05,
        }
    }

    /// Shape-compatible with the `tiny` model config (64-dim inputs).
    pub fn tiny(n: usize) -> Self {
        SynthSpec {
            n,
            dim: 64,
            n_classes: 10,
            proto_scale: 1.5,
            easy_noise: 0.3,
            hard_noise: 1.2,
            hard_frac: 0.2,
            label_noise: 0.05,
        }
    }
}

/// Fully materialised synthetic dataset.
pub struct SynthDataset {
    spec: SynthSpec,
    features: Vec<f32>, // row-major n x dim
    labels: Vec<u32>,
    tiers: Vec<Difficulty>,
}

impl SynthDataset {
    /// Materialise the full dataset for `(seed, spec)`.
    pub fn generate(seed: u64, spec: SynthSpec) -> Self {
        Self::generate_range(seed, spec, 0, usize::MAX)
    }

    /// Materialise only examples `[start, min(end, n))` — used by workers
    /// to hold just their shard.  Indexing into the result is still by
    /// *global* example id via `features()/label()` after offsetting with
    /// `start`; use [`SynthView`] for that.
    pub fn generate_range(seed: u64, spec: SynthSpec, start: usize, end: usize) -> Self {
        let end = end.min(spec.n);
        let start = start.min(end);
        let protos = Self::prototypes(seed, &spec);
        let count = end - start;
        let mut features = vec![0f32; count * spec.dim];
        let mut labels = vec![0u32; count];
        let mut tiers = vec![Difficulty::Easy; count];
        for i in 0..count {
            let global = start + i;
            // Independent stream per example: subsets are materialisable
            // without generating predecessors.
            let mut rng = Pcg64::new(seed ^ 0xDA7A_5E7, global as u64 + 1);
            let true_class = rng.next_below(spec.n_classes as u64) as u32;
            let hard = rng.next_f64() < spec.hard_frac;
            let noise = if hard { spec.hard_noise } else { spec.easy_noise };
            let mut label = true_class;
            if hard && rng.next_f64() < spec.label_noise {
                label = rng.next_below(spec.n_classes as u64) as u32;
            }
            let row = &mut features[i * spec.dim..(i + 1) * spec.dim];
            let proto = &protos[true_class as usize * spec.dim..(true_class as usize + 1) * spec.dim];
            for (v, p) in row.iter_mut().zip(proto) {
                *v = p + (rng.next_gaussian() as f32) * noise;
            }
            labels[i] = label;
            tiers[i] = if hard { Difficulty::Hard } else { Difficulty::Easy };
        }
        SynthDataset {
            spec,
            features,
            labels,
            tiers,
        }
    }

    /// The fixed class prototypes for `(seed, spec)`.
    fn prototypes(seed: u64, spec: &SynthSpec) -> Vec<f32> {
        let mut rng = Pcg64::new(seed ^ 0x9707_0E5, 0xC1A55);
        let mut protos = vec![0f32; spec.n_classes * spec.dim];
        // Scale so E||mu_c|| ~ proto_scale * sqrt(dim) / sqrt(dim) = proto_scale
        // per-coordinate std = proto_scale / sqrt(dim) keeps ||x|| O(1)-ish
        // relative to noise as dim grows.
        let std = spec.proto_scale / (spec.dim as f32).sqrt() * (spec.dim as f32).sqrt();
        // NOTE: prototypes use per-coordinate std = proto_scale, matching a
        // "unit-contrast image" regime where signal and noise are same order.
        let _ = std;
        rng.fill_gaussian(&mut protos, spec.proto_scale);
        protos
    }

    pub fn spec(&self) -> &SynthSpec {
        &self.spec
    }

    pub fn tier(&self, idx: usize) -> Difficulty {
        self.tiers[idx]
    }

    /// Fraction of hard examples actually realised.
    pub fn hard_fraction(&self) -> f64 {
        let hard = self.tiers.iter().filter(|t| **t == Difficulty::Hard).count();
        hard as f64 / self.tiers.len().max(1) as f64
    }
}

impl Dataset for SynthDataset {
    fn len(&self) -> usize {
        self.labels.len()
    }
    fn dim(&self) -> usize {
        self.spec.dim
    }
    fn n_classes(&self) -> usize {
        self.spec.n_classes
    }
    fn features(&self, idx: usize) -> &[f32] {
        &self.features[idx * self.spec.dim..(idx + 1) * self.spec.dim]
    }
    fn label(&self, idx: usize) -> u32 {
        self.labels[idx]
    }
}

/// A sub-view of a dataset over an explicit index list (train/valid/test
/// splits reuse one materialised dataset without copying rows).
pub struct IndexView<'a, D: Dataset> {
    base: &'a D,
    indices: Vec<usize>,
}

impl<'a, D: Dataset> IndexView<'a, D> {
    pub fn new(base: &'a D, indices: Vec<usize>) -> Self {
        IndexView { base, indices }
    }

    /// Global (base-dataset) index of view element `i`.
    pub fn global_index(&self, i: usize) -> usize {
        self.indices[i]
    }
}

impl<'a, D: Dataset> Dataset for IndexView<'a, D> {
    fn len(&self) -> usize {
        self.indices.len()
    }
    fn dim(&self) -> usize {
        self.base.dim()
    }
    fn n_classes(&self) -> usize {
        self.base.n_classes()
    }
    fn features(&self, idx: usize) -> &[f32] {
        self.base.features(self.indices[idx])
    }
    fn label(&self, idx: usize) -> u32 {
        self.base.label(self.indices[idx])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> SynthSpec {
        SynthSpec::tiny(200)
    }

    #[test]
    fn deterministic_across_generations() {
        let a = SynthDataset::generate(7, tiny_spec());
        let b = SynthDataset::generate(7, tiny_spec());
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.features, b.features);
    }

    #[test]
    fn different_seeds_differ() {
        let a = SynthDataset::generate(7, tiny_spec());
        let b = SynthDataset::generate(8, tiny_spec());
        assert_ne!(a.features, b.features);
    }

    #[test]
    fn range_generation_matches_full() {
        // The worker-shard path must produce byte-identical rows.
        let full = SynthDataset::generate(3, tiny_spec());
        let part = SynthDataset::generate_range(3, tiny_spec(), 50, 120);
        assert_eq!(part.len(), 70);
        for i in 0..70 {
            assert_eq!(part.features(i), full.features(50 + i));
            assert_eq!(part.label(i), full.label(50 + i));
        }
    }

    #[test]
    fn labels_in_range_and_all_classes_present() {
        let d = SynthDataset::generate(1, SynthSpec::tiny(1000));
        let mut seen = vec![false; 10];
        for i in 0..d.len() {
            let l = d.label(i) as usize;
            assert!(l < 10);
            seen[l] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn hard_fraction_near_spec() {
        let d = SynthDataset::generate(2, SynthSpec::tiny(5000));
        let f = d.hard_fraction();
        assert!((f - 0.2).abs() < 0.03, "hard fraction {f}");
    }

    #[test]
    fn hard_examples_are_noisier() {
        let d = SynthDataset::generate(4, SynthSpec::tiny(2000));
        // Compare mean feature L2 norm: hard rows carry much more noise.
        let (mut easy, mut hard) = (Vec::new(), Vec::new());
        for i in 0..d.len() {
            let norm: f32 = d.features(i).iter().map(|v| v * v).sum::<f32>().sqrt();
            match d.tier(i) {
                Difficulty::Easy => easy.push(norm),
                Difficulty::Hard => hard.push(norm),
            }
        }
        let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
        assert!(mean(&hard) > mean(&easy) * 1.2);
    }

    #[test]
    fn index_view_projects() {
        let d = SynthDataset::generate(5, tiny_spec());
        let view = IndexView::new(&d, vec![10, 20, 30]);
        assert_eq!(view.len(), 3);
        assert_eq!(view.features(1), d.features(20));
        assert_eq!(view.label(2), d.label(30));
        assert_eq!(view.global_index(0), 10);
    }
}
