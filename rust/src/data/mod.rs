//! Data substrate: datasets, shards, and batch assembly.
//!
//! The paper trains on SVHN-2 (~600k cropped 32x32x3 digit images, treated
//! permutation-invariantly, i.e. as flat 3072-vectors).  We do not have
//! SVHN in this environment, so `synth` generates a *synthetic SVHN-like*
//! task with the properties that actually matter for importance sampling
//! (see DESIGN.md §3): same input dimensionality and class count, and a
//! **heavy-tailed per-example gradient-norm distribution** induced by
//! explicit difficulty tiers + label noise.  The whole dataset is a pure
//! function of `(seed, spec)`, so master and workers regenerate it
//! identically instead of shipping ~7 GB over the wire.

pub mod batch;
pub mod npy;
pub mod synth;

pub use batch::BatchBuilder;
pub use npy::NpyDataset;
pub use synth::{Difficulty, SynthSpec, SynthDataset};

/// A labelled, in-memory dataset of flat f32 feature vectors.
pub trait Dataset: Send + Sync {
    /// Number of examples.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Feature dimensionality.
    fn dim(&self) -> usize;
    /// Number of classes.
    fn n_classes(&self) -> usize;
    /// Borrow the feature row of example `idx`.
    fn features(&self, idx: usize) -> &[f32];
    /// Label of example `idx`, in `[0, n_classes)`.
    fn label(&self, idx: usize) -> u32;
}

/// Contiguous index range `[start, end)` of a dataset assigned to a worker.
///
/// Sharding is by contiguous stripes so each worker's scoring sweep is a
/// sequential scan (cache-friendly) and the union of shards covers every
/// example exactly once.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    pub start: usize,
    pub end: usize,
}

impl Shard {
    pub fn len(&self) -> usize {
        self.end - self.start
    }
    pub fn is_empty(&self) -> bool {
        self.start >= self.end
    }
    pub fn indices(&self) -> std::ops::Range<usize> {
        self.start..self.end
    }
}

/// Split `[0, n)` into `k` near-equal contiguous shards (first `n % k`
/// shards get one extra element).
pub fn shards(n: usize, k: usize) -> Vec<Shard> {
    assert!(k > 0, "need at least one shard");
    let base = n / k;
    let extra = n % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for i in 0..k {
        let len = base + usize::from(i < extra);
        out.push(Shard {
            start,
            end: start + len,
        });
        start += len;
    }
    out
}

/// Deterministic train/validation/test split by index stride.
///
/// The paper splits 5% of SVHN for validation; we mirror that with an
/// interleaved split so every difficulty tier appears in every split.
#[derive(Debug, Clone, Copy)]
pub struct SplitSpec {
    /// Of every 100 examples, how many go to validation.
    pub valid_pct: usize,
    /// ... and how many to test.
    pub test_pct: usize,
}

impl Default for SplitSpec {
    fn default() -> Self {
        // paper: 5% validation; SVHN has a separate test set — we carve 10%.
        SplitSpec {
            valid_pct: 5,
            test_pct: 10,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Valid,
    Test,
}

pub fn split_of(idx: usize, spec: SplitSpec) -> Split {
    let r = idx % 100;
    if r < spec.valid_pct {
        Split::Valid
    } else if r < spec.valid_pct + spec.test_pct {
        Split::Test
    } else {
        Split::Train
    }
}

/// Index lists for the three splits of a dataset of size `n`.
pub fn split_indices(n: usize, spec: SplitSpec) -> (Vec<usize>, Vec<usize>, Vec<usize>) {
    let mut train = Vec::new();
    let mut valid = Vec::new();
    let mut test = Vec::new();
    for i in 0..n {
        match split_of(i, spec) {
            Split::Train => train.push(i),
            Split::Valid => valid.push(i),
            Split::Test => test.push(i),
        }
    }
    (train, valid, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_cover_exactly() {
        for (n, k) in [(10, 3), (7, 7), (100, 1), (5, 8)] {
            let ss = shards(n, k);
            assert_eq!(ss.len(), k);
            assert_eq!(ss.iter().map(Shard::len).sum::<usize>(), n);
            let mut pos = 0;
            for s in &ss {
                assert_eq!(s.start, pos);
                pos = s.end;
            }
            assert_eq!(pos, n);
        }
    }

    #[test]
    fn split_fractions_roughly_match() {
        let (tr, va, te) = split_indices(10_000, SplitSpec::default());
        assert_eq!(tr.len() + va.len() + te.len(), 10_000);
        assert_eq!(va.len(), 500);
        assert_eq!(te.len(), 1000);
    }

    #[test]
    fn splits_are_disjoint() {
        let (tr, va, te) = split_indices(500, SplitSpec::default());
        let mut all: Vec<usize> = tr.into_iter().chain(va).chain(te).collect();
        all.sort();
        assert_eq!(all, (0..500).collect::<Vec<_>>());
    }
}
