//! Typed run configuration: every knob of the master/worker/database
//! topology, loadable from a JSON file and overridable from the CLI.
//!
//! The two named hyperparameter settings of the paper's §5 figures are
//! provided as presets: `setting_a` (lr 0.01, smoothing +10) and
//! `setting_b` (lr 0.001, smoothing +1).

use std::path::Path;

use anyhow::{Context, Result};

use crate::sampler::strategy::StrategyKind;
use crate::util::cli::Args;
use crate::util::json::Json;

/// How minibatches are drawn on the master.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrainerKind {
    /// Importance sampling from the weight store (the paper's method).
    Issgd,
    /// Uniform sampling, coef = 1 (the paper's "regular SGD" baseline —
    /// shares the same train_step artifact).
    UniformSgd,
}

/// Synchronisation discipline between master and workers (paper §4.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncMode {
    /// Barriers enforced: after every parameter publish the workers
    /// re-score the entire training set before the master proceeds.
    /// Oracle-equivalent; used for sanity checks.
    Exact,
    /// Fire-and-forget: the master never waits; weights are stale to
    /// varying degrees.  The practical mode.
    Relaxed,
}

/// Units for the staleness threshold (§B.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StalenessUnit {
    /// Wall-clock nanoseconds of the store clock (live runs; the paper's
    /// "4 seconds").
    Nanos,
    /// Parameter-version distance (deterministic simulation runs).
    Versions,
}

#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Model/artifact config name (`tiny`, `small`, `paper`, `large`).
    pub model: String,
    /// Number of synthetic examples (train+valid+test before the split).
    pub n_examples: usize,
    /// Master SGD steps to run.
    pub steps: u64,
    pub lr: f32,
    /// §B.3 additive smoothing constant on probability weights.
    pub smoothing: f64,
    /// Adaptive smoothing (§B.3's suggested extension): when set, the
    /// fixed constant is replaced per-step by the constant that brings the
    /// proposal's normalised entropy up to this target in [0, 1].
    pub adaptive_entropy: Option<f64>,
    /// How scores become sampling mass (and what workers score) — the
    /// paper's grad-norm exact IS by default; see `sampler::strategy`.
    pub strategy: StrategyKind,
    pub trainer: TrainerKind,
    pub sync: SyncMode,
    /// Number of scoring workers.
    pub n_workers: usize,
    /// Scoring batches each (simulated) worker completes per master step —
    /// the worker:master speed ratio of the paper's testbed.
    pub worker_batches_per_step: usize,
    /// Master publishes parameters every this many steps ("a non-trivial
    /// amount of training in-between", §4.2).
    pub param_push_every: u64,
    /// Staleness filter threshold; `None` disables (§B.1).
    pub staleness_threshold: Option<u64>,
    pub staleness_unit: StalenessUnit,
    /// Evaluate train/test prediction error every this many steps (0 = never).
    pub eval_every: u64,
    /// Cap on eval batches per split per evaluation (0 = whole split).
    pub eval_max_batches: usize,
    /// Variance monitor (fig. 4) cadence in steps (0 = off).
    pub monitor_every: u64,
    /// Alternate smoothing constant reported by the monitor (fig. 4 shows
    /// the actual and one alternate).
    pub monitor_alt_smoothing: f64,
    /// Initial probability weight before any worker has scored (uniform).
    pub init_weight: f64,
    /// Experiment seed: shapes data, init, and sampling.
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "small".into(),
            n_examples: 4096,
            steps: 300,
            lr: 0.01,
            smoothing: 10.0,
            adaptive_entropy: None,
            strategy: StrategyKind::GradNormIs,
            trainer: TrainerKind::Issgd,
            sync: SyncMode::Relaxed,
            n_workers: 3,
            worker_batches_per_step: 2,
            param_push_every: 5,
            staleness_threshold: None,
            staleness_unit: StalenessUnit::Versions,
            eval_every: 25,
            eval_max_batches: 4,
            monitor_every: 0,
            monitor_alt_smoothing: 1.0,
            init_weight: 1.0,
            seed: 0,
        }
    }
}

impl RunConfig {
    /// Paper §5 figure setting (a): higher lr, heavier smoothing.
    pub fn setting_a() -> Self {
        RunConfig {
            lr: 0.01,
            smoothing: 10.0,
            ..Default::default()
        }
    }

    /// Paper §5 figure setting (b): lower lr, light smoothing.
    pub fn setting_b() -> Self {
        RunConfig {
            lr: 0.001,
            smoothing: 1.0,
            ..Default::default()
        }
    }

    /// Fast test-scale config against the `tiny` artifacts.
    pub fn tiny_test() -> Self {
        RunConfig {
            model: "tiny".into(),
            n_examples: 512,
            steps: 40,
            lr: 0.05,
            smoothing: 1.0,
            eval_every: 10,
            eval_max_batches: 2,
            monitor_every: 0,
            ..Default::default()
        }
    }

    // ---- JSON ----------------------------------------------------------

    pub fn from_json(json: &Json) -> Result<RunConfig> {
        let d = RunConfig::default();
        let get_u = |k: &str, dv: usize| -> Result<usize> {
            match json.get(k) {
                None => Ok(dv),
                Some(v) => v.as_usize().with_context(|| format!("field {k}")),
            }
        };
        let get_f = |k: &str, dv: f64| -> Result<f64> {
            match json.get(k) {
                None => Ok(dv),
                Some(v) => v.as_f64().with_context(|| format!("field {k}")),
            }
        };
        let trainer = match json.get("trainer").and_then(Json::as_str) {
            None => d.trainer,
            Some("issgd") => TrainerKind::Issgd,
            Some("sgd") => TrainerKind::UniformSgd,
            Some(other) => anyhow::bail!("unknown trainer {other:?} (issgd|sgd)"),
        };
        let sync = match json.get("sync").and_then(Json::as_str) {
            None => d.sync,
            Some("exact") => SyncMode::Exact,
            Some("relaxed") => SyncMode::Relaxed,
            Some(other) => anyhow::bail!("unknown sync mode {other:?} (exact|relaxed)"),
        };
        let staleness_unit = match json.get("staleness_unit").and_then(Json::as_str) {
            None => d.staleness_unit,
            Some("nanos") => StalenessUnit::Nanos,
            Some("versions") => StalenessUnit::Versions,
            Some(other) => anyhow::bail!("unknown staleness unit {other:?}"),
        };
        let adaptive_entropy = match json.get("adaptive_entropy") {
            None | Some(Json::Null) => d.adaptive_entropy,
            Some(v) => Some(v.as_f64().context("adaptive_entropy")?),
        };
        let strategy = match json.get("strategy").and_then(Json::as_str) {
            None => d.strategy,
            Some(s) => StrategyKind::parse(s)?,
        };
        let staleness_threshold = match json.get("staleness_threshold") {
            None | Some(Json::Null) => d.staleness_threshold,
            Some(v) => Some(v.as_usize().context("staleness_threshold")? as u64),
        };
        Ok(RunConfig {
            model: json
                .get("model")
                .and_then(Json::as_str)
                .unwrap_or(&d.model)
                .to_string(),
            n_examples: get_u("n_examples", d.n_examples)?,
            steps: get_u("steps", d.steps as usize)? as u64,
            lr: get_f("lr", d.lr as f64)? as f32,
            smoothing: get_f("smoothing", d.smoothing)?,
            adaptive_entropy,
            strategy,
            trainer,
            sync,
            n_workers: get_u("n_workers", d.n_workers)?,
            worker_batches_per_step: get_u("worker_batches_per_step", d.worker_batches_per_step)?,
            param_push_every: get_u("param_push_every", d.param_push_every as usize)? as u64,
            staleness_threshold,
            staleness_unit,
            eval_every: get_u("eval_every", d.eval_every as usize)? as u64,
            eval_max_batches: get_u("eval_max_batches", d.eval_max_batches)?,
            monitor_every: get_u("monitor_every", d.monitor_every as usize)? as u64,
            monitor_alt_smoothing: get_f("monitor_alt_smoothing", d.monitor_alt_smoothing)?,
            init_weight: get_f("init_weight", d.init_weight)?,
            seed: get_u("seed", d.seed as usize)? as u64,
        })
    }

    pub fn load(path: &Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        Self::from_json(&json)
    }

    // ---- CLI overrides ---------------------------------------------------

    /// The option names `apply_args` consumes (callers pass these to
    /// `cli::parse` so typos are rejected).
    pub const CLI_OPTS: &'static [&'static str] = &[
        "config", "model", "n-examples", "steps", "lr", "smoothing", "target-entropy", "trainer", "sync",
        "strategy", "workers", "worker-batches", "push-every", "staleness", "staleness-unit",
        "eval-every", "eval-max-batches", "monitor-every", "alt-smoothing", "init-weight",
        "seed",
    ];

    /// Overlay CLI options onto `self`.
    pub fn apply_args(mut self, args: &Args) -> Result<RunConfig> {
        if let Some(m) = args.get("model") {
            self.model = m.to_string();
        }
        self.n_examples = args.get_parse("n-examples", self.n_examples)?;
        self.steps = args.get_parse("steps", self.steps)?;
        self.lr = args.get_parse("lr", self.lr)?;
        self.smoothing = args.get_parse("smoothing", self.smoothing)?;
        if let Some(t) = args.get("target-entropy") {
            self.adaptive_entropy = if t == "off" {
                None
            } else {
                let v: f64 = t.parse().context("--target-entropy")?;
                anyhow::ensure!((0.0..=1.0).contains(&v), "--target-entropy must be in [0,1]");
                Some(v)
            };
        }
        if let Some(s) = args.get("strategy") {
            self.strategy = StrategyKind::parse(s)?;
        }
        if let Some(t) = args.get("trainer") {
            self.trainer = match t {
                "issgd" => TrainerKind::Issgd,
                "sgd" => TrainerKind::UniformSgd,
                other => anyhow::bail!("unknown trainer {other:?} (issgd|sgd)"),
            };
        }
        if let Some(s) = args.get("sync") {
            self.sync = match s {
                "exact" => SyncMode::Exact,
                "relaxed" => SyncMode::Relaxed,
                other => anyhow::bail!("unknown sync mode {other:?} (exact|relaxed)"),
            };
        }
        self.n_workers = args.get_parse("workers", self.n_workers)?;
        self.worker_batches_per_step =
            args.get_parse("worker-batches", self.worker_batches_per_step)?;
        self.param_push_every = args.get_parse("push-every", self.param_push_every)?;
        if let Some(s) = args.get("staleness") {
            self.staleness_threshold = if s == "off" {
                None
            } else {
                Some(s.parse::<u64>().context("--staleness")?)
            };
        }
        if let Some(u) = args.get("staleness-unit") {
            self.staleness_unit = match u {
                "nanos" => StalenessUnit::Nanos,
                "versions" => StalenessUnit::Versions,
                other => anyhow::bail!("unknown staleness unit {other:?}"),
            };
        }
        self.eval_every = args.get_parse("eval-every", self.eval_every)?;
        self.eval_max_batches = args.get_parse("eval-max-batches", self.eval_max_batches)?;
        self.monitor_every = args.get_parse("monitor-every", self.monitor_every)?;
        self.monitor_alt_smoothing =
            args.get_parse("alt-smoothing", self.monitor_alt_smoothing)?;
        self.init_weight = args.get_parse("init-weight", self.init_weight)?;
        self.seed = args.get_parse("seed", self.seed)?;
        self.validate()?;
        Ok(self)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.n_examples > 0, "n_examples must be positive");
        anyhow::ensure!(self.lr > 0.0 && self.lr.is_finite(), "lr must be positive");
        anyhow::ensure!(self.smoothing >= 0.0, "smoothing must be >= 0");
        if let Some(t) = self.adaptive_entropy {
            anyhow::ensure!((0.0..=1.0).contains(&t), "adaptive_entropy must be in [0,1]");
            // The entropy→constant solver inverts the `w + c` mass form;
            // it has no inverse for the other transforms.
            anyhow::ensure!(
                self.strategy == StrategyKind::GradNormIs,
                "adaptive_entropy requires the grad-norm strategy (got {})",
                self.strategy.name()
            );
        }
        anyhow::ensure!(self.n_workers > 0, "need at least one worker");
        anyhow::ensure!(self.param_push_every > 0, "param_push_every must be >= 1");
        anyhow::ensure!(self.init_weight >= 0.0, "init_weight must be >= 0");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli;

    #[test]
    fn presets_match_paper() {
        let a = RunConfig::setting_a();
        assert_eq!((a.lr, a.smoothing), (0.01, 10.0));
        let b = RunConfig::setting_b();
        assert_eq!((b.lr, b.smoothing), (0.001, 1.0));
    }

    #[test]
    fn json_roundtrip_fields() {
        let j = Json::parse(
            r#"{"model": "tiny", "steps": 77, "lr": 0.5, "trainer": "sgd",
                "sync": "exact", "staleness_threshold": 4, "staleness_unit": "versions"}"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.model, "tiny");
        assert_eq!(c.steps, 77);
        assert_eq!(c.trainer, TrainerKind::UniformSgd);
        assert_eq!(c.sync, SyncMode::Exact);
        assert_eq!(c.staleness_threshold, Some(4));
        // untouched fields keep defaults
        assert_eq!(c.n_workers, RunConfig::default().n_workers);
    }

    #[test]
    fn json_rejects_bad_enums() {
        let j = Json::parse(r#"{"trainer": "magic"}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn cli_overrides() {
        let argv: Vec<String> = "--steps 9 --lr 0.25 --trainer sgd --staleness off"
            .split_whitespace()
            .map(String::from)
            .collect();
        let args = cli::parse(&argv, RunConfig::CLI_OPTS).unwrap();
        let c = RunConfig {
            staleness_threshold: Some(10),
            ..RunConfig::default()
        }
        .apply_args(&args)
        .unwrap();
        assert_eq!(c.steps, 9);
        assert_eq!(c.lr, 0.25);
        assert_eq!(c.trainer, TrainerKind::UniformSgd);
        assert_eq!(c.staleness_threshold, None);
    }

    #[test]
    fn strategy_knob_parses_and_guards_adaptive_entropy() {
        let j = Json::parse(r#"{"strategy": "loss-reject"}"#).unwrap();
        assert_eq!(RunConfig::from_json(&j).unwrap().strategy, StrategyKind::LossReject);
        let j = Json::parse(r#"{"strategy": "roulette"}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
        let argv: Vec<String> = ["--strategy", "exp3"].iter().map(|s| s.to_string()).collect();
        let args = cli::parse(&argv, RunConfig::CLI_OPTS).unwrap();
        let c = RunConfig::default().apply_args(&args).unwrap();
        assert_eq!(c.strategy, StrategyKind::Exp3);
        // Adaptive entropy inverts w + c: only the default strategy has it.
        let c = RunConfig {
            adaptive_entropy: Some(0.9),
            strategy: StrategyKind::PowerIs,
            ..RunConfig::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn validation_catches_nonsense() {
        let mut c = RunConfig::default();
        c.n_workers = 0;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.lr = -1.0;
        assert!(c.validate().is_err());
    }
}
