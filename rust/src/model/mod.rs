//! Host-side model parameters: initialisation, (de)serialisation for the
//! weight-store wire, and conversion to the flat `(W_0, b_0, ...)` operand
//! list the AOT entry points expect.
//!
//! The actual math lives in the HLO artifacts; rust only owns the bytes.

pub mod checkpoint;

pub use checkpoint::Checkpoint;

use anyhow::Result;

use crate::runtime::Manifest;
use crate::util::rng::Pcg64;

/// One dense layer's parameters, row-major `W: (d_in, d_out)` + `b: (d_out,)`.
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub d_in: usize,
    pub d_out: usize,
}

/// Full parameter set for one model config.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSet {
    pub layers: Vec<Layer>,
}

impl ParamSet {
    /// He-normal initialisation (matches the python-side `init_params`
    /// convention: std = sqrt(2/d_in), zero biases).  The exact draws
    /// differ from jax's — irrelevant, since rust owns initialisation in
    /// every run path.
    pub fn init_he(manifest: &Manifest, rng: &mut Pcg64) -> ParamSet {
        let layers = manifest
            .layers
            .iter()
            .map(|spec| {
                let std = (2.0 / spec.d_in as f32).sqrt();
                let mut w = vec![0f32; spec.d_in * spec.d_out];
                rng.fill_gaussian(&mut w, std);
                Layer {
                    w,
                    b: vec![0f32; spec.d_out],
                    d_in: spec.d_in,
                    d_out: spec.d_out,
                }
            })
            .collect();
        ParamSet { layers }
    }

    /// Total scalar parameter count.
    pub fn n_params(&self) -> usize {
        self.layers.iter().map(|l| l.w.len() + l.b.len()).sum()
    }

    /// Serialise to the wire format used for master→worker broadcast:
    /// plain little-endian f32s in layer order (shapes come from the
    /// manifest both sides share).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.n_params() * 4);
        for layer in &self.layers {
            for v in layer.w.iter().chain(layer.b.iter()) {
                out.extend(v.to_le_bytes());
            }
        }
        out
    }

    /// Inverse of [`ParamSet::to_bytes`]; validates the byte count against
    /// the manifest.
    pub fn from_bytes(manifest: &Manifest, bytes: &[u8]) -> Result<ParamSet> {
        let expect = manifest.n_params * 4;
        anyhow::ensure!(
            bytes.len() == expect,
            "parameter blob is {} bytes, manifest expects {}",
            bytes.len(),
            expect
        );
        let mut pos = 0usize;
        let mut take = |n: usize| {
            let s = &bytes[pos..pos + n * 4];
            pos += n * 4;
            s.chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect::<Vec<f32>>()
        };
        let layers = manifest
            .layers
            .iter()
            .map(|spec| Layer {
                w: take(spec.d_in * spec.d_out),
                b: take(spec.d_out),
                d_in: spec.d_in,
                d_out: spec.d_out,
            })
            .collect();
        Ok(ParamSet { layers })
    }

    /// L2 norm of the flattened parameter vector (monitoring).
    pub fn l2_norm(&self) -> f64 {
        self.layers
            .iter()
            .flat_map(|l| l.w.iter().chain(l.b.iter()))
            .map(|&v| (v as f64) * (v as f64))
            .sum::<f64>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::LayerSpec;

    fn manifest() -> Manifest {
        Manifest::synthetic_for_tests(vec![
            LayerSpec { d_in: 8, d_out: 4 },
            LayerSpec { d_in: 4, d_out: 3 },
        ])
    }

    #[test]
    fn init_shapes_and_counts() {
        let m = manifest();
        let p = ParamSet::init_he(&m, &mut Pcg64::seeded(1));
        assert_eq!(p.layers.len(), 2);
        assert_eq!(p.layers[0].w.len(), 32);
        assert_eq!(p.layers[1].b.len(), 3);
        assert_eq!(p.n_params(), 32 + 4 + 12 + 3);
        assert_eq!(p.n_params(), m.n_params);
        // biases zero, weights not all zero
        assert!(p.layers[0].b.iter().all(|&v| v == 0.0));
        assert!(p.layers[0].w.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn init_is_deterministic_per_seed() {
        let m = manifest();
        let a = ParamSet::init_he(&m, &mut Pcg64::seeded(9));
        let b = ParamSet::init_he(&m, &mut Pcg64::seeded(9));
        let c = ParamSet::init_he(&m, &mut Pcg64::seeded(10));
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn he_std_is_plausible() {
        let m = Manifest::synthetic_for_tests(vec![LayerSpec {
            d_in: 512,
            d_out: 256,
        }]);
        let p = ParamSet::init_he(&m, &mut Pcg64::seeded(2));
        let w = &p.layers[0].w;
        let mean: f64 = w.iter().map(|&v| v as f64).sum::<f64>() / w.len() as f64;
        let var: f64 =
            w.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / w.len() as f64;
        let want = 2.0 / 512.0;
        assert!(mean.abs() < 0.002, "mean {mean}");
        assert!((var - want).abs() / want < 0.1, "var {var} want {want}");
    }

    #[test]
    fn bytes_roundtrip() {
        let m = manifest();
        let p = ParamSet::init_he(&m, &mut Pcg64::seeded(3));
        let bytes = p.to_bytes();
        assert_eq!(bytes.len(), p.n_params() * 4);
        let q = ParamSet::from_bytes(&m, &bytes).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn from_bytes_validates_length() {
        let m = manifest();
        assert!(ParamSet::from_bytes(&m, &[0u8; 7]).is_err());
    }

    #[test]
    fn l2_norm_zero_for_zero_params() {
        let m = manifest();
        let mut p = ParamSet::init_he(&m, &mut Pcg64::seeded(4));
        for l in &mut p.layers {
            l.w.fill(0.0);
            l.b.fill(0.0);
        }
        assert_eq!(p.l2_norm(), 0.0);
    }
}
