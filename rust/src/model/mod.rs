//! Host-side model parameters: initialisation, (de)serialisation for the
//! weight-store wire, and conversion to the flat `(W_0, b_0, ...)` operand
//! list the AOT entry points expect.
//!
//! The actual math lives in the HLO artifacts; rust only owns the bytes.

pub mod checkpoint;

pub use checkpoint::Checkpoint;

use anyhow::Result;

use crate::runtime::Manifest;
use crate::util::rng::Pcg64;
use crate::weightstore::ParamsDelta;

/// Canonical weight-store chunk name of layer `i` — the naming contract
/// between the publisher ([`ParamSet::to_layer_chunks`]) and subscribers
/// ([`ParamSet::apply_delta`]).  One chunk per layer, `W_i ‖ b_i` in
/// [`ParamSet::to_bytes`] order, so concatenating the chunks in layout
/// order reproduces the flat blob byte-exactly.
pub fn layer_chunk_name(i: usize) -> String {
    format!("layer{i}")
}

/// One dense layer's parameters, row-major `W: (d_in, d_out)` + `b: (d_out,)`.
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub d_in: usize,
    pub d_out: usize,
}

/// Full parameter set for one model config.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSet {
    pub layers: Vec<Layer>,
}

impl ParamSet {
    /// He-normal initialisation (matches the python-side `init_params`
    /// convention: std = sqrt(2/d_in), zero biases).  The exact draws
    /// differ from jax's — irrelevant, since rust owns initialisation in
    /// every run path.
    pub fn init_he(manifest: &Manifest, rng: &mut Pcg64) -> ParamSet {
        let layers = manifest
            .layers
            .iter()
            .map(|spec| {
                let std = (2.0 / spec.d_in as f32).sqrt();
                let mut w = vec![0f32; spec.d_in * spec.d_out];
                rng.fill_gaussian(&mut w, std);
                Layer {
                    w,
                    b: vec![0f32; spec.d_out],
                    d_in: spec.d_in,
                    d_out: spec.d_out,
                }
            })
            .collect();
        ParamSet { layers }
    }

    /// Total scalar parameter count.
    pub fn n_params(&self) -> usize {
        self.layers.iter().map(|l| l.w.len() + l.b.len()).sum()
    }

    /// Serialise to the wire format used for master→worker broadcast:
    /// plain little-endian f32s in layer order (shapes come from the
    /// manifest both sides share).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.n_params() * 4);
        for layer in &self.layers {
            for v in layer.w.iter().chain(layer.b.iter()) {
                out.extend(v.to_le_bytes());
            }
        }
        out
    }

    /// Serialise one layer's chunk (`W_i ‖ b_i`, little-endian f32s) —
    /// the unit of layer-wise parameter propagation.
    pub fn layer_bytes(&self, i: usize) -> Vec<u8> {
        let l = &self.layers[i];
        let mut out = Vec::with_capacity((l.w.len() + l.b.len()) * 4);
        for v in l.w.iter().chain(l.b.iter()) {
            out.extend(v.to_le_bytes());
        }
        out
    }

    /// All layers as named chunks in layout order — the store's
    /// full-layout publish ([`crate::weightstore::WeightStore::push_params_layers`]).
    pub fn to_layer_chunks(&self) -> Vec<(String, Vec<u8>)> {
        (0..self.layers.len())
            .map(|i| (layer_chunk_name(i), self.layer_bytes(i)))
            .collect()
    }

    /// Apply a params delta in place: a full delta rebuilds from the
    /// concatenated blob (validated against the manifest), an incremental
    /// one overwrites only the named layers — the O(dirty layers)
    /// counterpart of `from_bytes` on the whole blob.
    ///
    /// All-or-nothing: every chunk is resolved and size-checked before any
    /// layer is mutated, so a malformed delta never leaves the set
    /// half-patched (callers retry or keep evaluating the last good
    /// parameters).
    pub fn apply_delta(&mut self, manifest: &Manifest, delta: &ParamsDelta) -> Result<()> {
        if delta.full {
            *self = ParamSet::from_bytes(manifest, &delta.to_blob()?)?;
            return Ok(());
        }
        // Pass 1: resolve + validate everything without touching `self`.
        let mut resolved: Vec<usize> = Vec::with_capacity(delta.layers.len());
        for chunk in &delta.layers {
            if chunk.name.is_empty() {
                // The unnamed chunk is the store's whole-blob pseudo-layer
                // (a blob-published layout); it replaces the whole set.
                anyhow::ensure!(
                    chunk.bytes.len() == manifest.n_params * 4,
                    "whole-blob chunk is {} bytes, manifest expects {}",
                    chunk.bytes.len(),
                    manifest.n_params * 4
                );
                resolved.push(usize::MAX); // sentinel: full rebuild
                continue;
            }
            // Parse the index out of the canonical "layer{i}" name — O(1),
            // no per-candidate allocation (refreshes run per sync on the
            // worker/peer hot path).
            let i: usize = chunk
                .name
                .strip_prefix("layer")
                .and_then(|s| s.parse().ok())
                .filter(|&i| i < self.layers.len())
                .ok_or_else(|| {
                    anyhow::anyhow!("params delta names unknown layer {:?}", chunk.name)
                })?;
            let l = &self.layers[i];
            let expect = (l.w.len() + l.b.len()) * 4;
            anyhow::ensure!(
                chunk.bytes.len() == expect,
                "layer {:?} chunk is {} bytes, shape expects {expect}",
                chunk.name,
                chunk.bytes.len()
            );
            resolved.push(i);
        }
        // Pass 2: apply (infallible).
        for (chunk, &i) in delta.layers.iter().zip(&resolved) {
            if i == usize::MAX {
                // Validated above; from_bytes can no longer fail on size.
                *self = ParamSet::from_bytes(manifest, &chunk.bytes)?;
                continue;
            }
            let l = &mut self.layers[i];
            let mut vals = chunk
                .bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()));
            for v in l.w.iter_mut().chain(l.b.iter_mut()) {
                *v = vals.next().unwrap();
            }
        }
        Ok(())
    }

    /// Bootstrap a parameter set from a **full** delta.
    pub fn from_delta(manifest: &Manifest, delta: &ParamsDelta) -> Result<ParamSet> {
        anyhow::ensure!(delta.full, "bootstrap requires a full params delta");
        ParamSet::from_bytes(manifest, &delta.to_blob()?)
    }

    /// Inverse of [`ParamSet::to_bytes`]; validates the byte count against
    /// the manifest.
    pub fn from_bytes(manifest: &Manifest, bytes: &[u8]) -> Result<ParamSet> {
        let expect = manifest.n_params * 4;
        anyhow::ensure!(
            bytes.len() == expect,
            "parameter blob is {} bytes, manifest expects {}",
            bytes.len(),
            expect
        );
        let mut pos = 0usize;
        let mut take = |n: usize| {
            let s = &bytes[pos..pos + n * 4];
            pos += n * 4;
            s.chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect::<Vec<f32>>()
        };
        let layers = manifest
            .layers
            .iter()
            .map(|spec| Layer {
                w: take(spec.d_in * spec.d_out),
                b: take(spec.d_out),
                d_in: spec.d_in,
                d_out: spec.d_out,
            })
            .collect();
        Ok(ParamSet { layers })
    }

    /// L2 norm of the flattened parameter vector (monitoring).
    pub fn l2_norm(&self) -> f64 {
        self.layers
            .iter()
            .flat_map(|l| l.w.iter().chain(l.b.iter()))
            .map(|&v| (v as f64) * (v as f64))
            .sum::<f64>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::LayerSpec;

    fn manifest() -> Manifest {
        Manifest::synthetic_for_tests(vec![
            LayerSpec { d_in: 8, d_out: 4 },
            LayerSpec { d_in: 4, d_out: 3 },
        ])
    }

    #[test]
    fn init_shapes_and_counts() {
        let m = manifest();
        let p = ParamSet::init_he(&m, &mut Pcg64::seeded(1));
        assert_eq!(p.layers.len(), 2);
        assert_eq!(p.layers[0].w.len(), 32);
        assert_eq!(p.layers[1].b.len(), 3);
        assert_eq!(p.n_params(), 32 + 4 + 12 + 3);
        assert_eq!(p.n_params(), m.n_params);
        // biases zero, weights not all zero
        assert!(p.layers[0].b.iter().all(|&v| v == 0.0));
        assert!(p.layers[0].w.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn init_is_deterministic_per_seed() {
        let m = manifest();
        let a = ParamSet::init_he(&m, &mut Pcg64::seeded(9));
        let b = ParamSet::init_he(&m, &mut Pcg64::seeded(9));
        let c = ParamSet::init_he(&m, &mut Pcg64::seeded(10));
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn he_std_is_plausible() {
        let m = Manifest::synthetic_for_tests(vec![LayerSpec {
            d_in: 512,
            d_out: 256,
        }]);
        let p = ParamSet::init_he(&m, &mut Pcg64::seeded(2));
        let w = &p.layers[0].w;
        let mean: f64 = w.iter().map(|&v| v as f64).sum::<f64>() / w.len() as f64;
        let var: f64 =
            w.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / w.len() as f64;
        let want = 2.0 / 512.0;
        assert!(mean.abs() < 0.002, "mean {mean}");
        assert!((var - want).abs() / want < 0.1, "var {var} want {want}");
    }

    #[test]
    fn bytes_roundtrip() {
        let m = manifest();
        let p = ParamSet::init_he(&m, &mut Pcg64::seeded(3));
        let bytes = p.to_bytes();
        assert_eq!(bytes.len(), p.n_params() * 4);
        let q = ParamSet::from_bytes(&m, &bytes).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn from_bytes_validates_length() {
        let m = manifest();
        assert!(ParamSet::from_bytes(&m, &[0u8; 7]).is_err());
    }

    #[test]
    fn layer_chunks_concatenate_to_the_flat_blob() {
        let m = manifest();
        let p = ParamSet::init_he(&m, &mut Pcg64::seeded(5));
        let chunks = p.to_layer_chunks();
        assert_eq!(chunks.len(), 2);
        assert_eq!(chunks[0].0, "layer0");
        assert_eq!(chunks[1].0, "layer1");
        let concat: Vec<u8> = chunks.iter().flat_map(|(_, b)| b.iter().copied()).collect();
        assert_eq!(concat, p.to_bytes());
    }

    #[test]
    fn apply_delta_partial_updates_only_named_layers() {
        use crate::weightstore::{LayerChunk, ParamsDelta};
        let m = manifest();
        let mut p = ParamSet::init_he(&m, &mut Pcg64::seeded(6));
        let q = ParamSet::init_he(&m, &mut Pcg64::seeded(7));
        // Ship only layer 1 of q into p.
        let delta = ParamsDelta {
            version: 2,
            full: false,
            layers: vec![LayerChunk {
                name: "layer1".into(),
                version: 2,
                bytes: q.layer_bytes(1),
            }],
        };
        let p0_before = p.layers[0].clone();
        p.apply_delta(&m, &delta).unwrap();
        assert_eq!(p.layers[0], p0_before, "untouched layer changed");
        assert_eq!(p.layers[1], q.layers[1], "named layer not applied");
        // Unknown names and wrong sizes are hard errors.
        let bad = ParamsDelta {
            version: 3,
            full: false,
            layers: vec![LayerChunk {
                name: "layer9".into(),
                version: 3,
                bytes: q.layer_bytes(1),
            }],
        };
        assert!(p.apply_delta(&m, &bad).is_err());
        let short = ParamsDelta {
            version: 3,
            full: false,
            layers: vec![LayerChunk {
                name: "layer1".into(),
                version: 3,
                bytes: vec![0u8; 4],
            }],
        };
        assert!(p.apply_delta(&m, &short).is_err());
    }

    #[test]
    fn full_delta_bootstraps_a_param_set() {
        use crate::weightstore::{LayerChunk, ParamsDelta};
        let m = manifest();
        let p = ParamSet::init_he(&m, &mut Pcg64::seeded(8));
        let delta = ParamsDelta {
            version: 1,
            full: true,
            layers: p
                .to_layer_chunks()
                .into_iter()
                .map(|(name, bytes)| LayerChunk {
                    name,
                    version: 1,
                    bytes,
                })
                .collect(),
        };
        let q = ParamSet::from_delta(&m, &delta).unwrap();
        assert_eq!(p, q);
        // A partial delta cannot bootstrap.
        let mut partial = delta.clone();
        partial.full = false;
        assert!(ParamSet::from_delta(&m, &partial).is_err());
    }

    #[test]
    fn l2_norm_zero_for_zero_params() {
        let m = manifest();
        let mut p = ParamSet::init_he(&m, &mut Pcg64::seeded(4));
        for l in &mut p.layers {
            l.w.fill(0.0);
            l.b.fill(0.0);
        }
        assert_eq!(p.l2_norm(), 0.0);
    }
}
