//! Checkpointing: persist and restore training state.
//!
//! Format: a small JSON header (model config, step, version, seed, shape
//! fingerprint) followed by the raw little-endian f32 parameter blob —
//! the same wire format the weight store broadcasts, so a checkpoint is
//! byte-compatible with `ParamSet::to_bytes`.  Writes go through a temp
//! file + rename for crash safety.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::runtime::Manifest;
use crate::util::json::Json;

use super::ParamSet;

const MAGIC: &[u8; 8] = b"ISSGDCKP";

/// Everything needed to resume a run.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub model: String,
    pub step: u64,
    pub version: u64,
    pub seed: u64,
    pub params: ParamSet,
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        let header = Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("step", Json::Num(self.step as f64)),
            ("version", Json::Num(self.version as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("n_params", Json::Num(self.params.n_params() as f64)),
            (
                "layer_dims",
                Json::Arr(
                    self.params
                        .layers
                        .iter()
                        .map(|l| {
                            Json::Arr(vec![Json::Num(l.d_in as f64), Json::Num(l.d_out as f64)])
                        })
                        .collect(),
                ),
            ),
        ])
        .to_string();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let tmp = path.with_extension("ckpt.tmp");
        {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(MAGIC)?;
            f.write_all(&(header.len() as u32).to_le_bytes())?;
            f.write_all(header.as_bytes())?;
            f.write_all(&self.params.to_bytes())?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Load and validate against the manifest the engine will run with.
    pub fn load(path: &Path, manifest: &Manifest) -> Result<Checkpoint> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening checkpoint {}", path.display()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not an issgd checkpoint");
        let mut len_b = [0u8; 4];
        f.read_exact(&mut len_b)?;
        let mut header = vec![0u8; u32::from_le_bytes(len_b) as usize];
        f.read_exact(&mut header)?;
        let header = Json::parse(std::str::from_utf8(&header)?)
            .map_err(|e| anyhow::anyhow!("checkpoint header: {e}"))?;
        let model = header.req_str("model")?.to_string();
        anyhow::ensure!(
            model == manifest.config,
            "checkpoint is for model {model:?}, engine runs {:?}",
            manifest.config
        );
        let n_params = header.req_usize("n_params")?;
        anyhow::ensure!(
            n_params == manifest.n_params,
            "checkpoint has {n_params} params, manifest expects {}",
            manifest.n_params
        );
        let mut blob = Vec::new();
        f.read_to_end(&mut blob)?;
        let params = ParamSet::from_bytes(manifest, &blob)?;
        Ok(Checkpoint {
            model,
            step: header.req_usize("step")? as u64,
            version: header.req_usize("version")? as u64,
            seed: header.req_usize("seed")? as u64,
            params,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::LayerSpec;
    use crate::util::rng::Pcg64;

    fn manifest() -> Manifest {
        Manifest::synthetic_for_tests(vec![
            LayerSpec { d_in: 6, d_out: 4 },
            LayerSpec { d_in: 4, d_out: 2 },
        ])
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("issgd-ckpt-{}-{name}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let m = manifest();
        let ckpt = Checkpoint {
            model: "synthetic".into(),
            step: 123,
            version: 45,
            seed: 6,
            params: ParamSet::init_he(&m, &mut Pcg64::seeded(1)),
        };
        let p = tmp("roundtrip");
        ckpt.save(&p).unwrap();
        let back = Checkpoint::load(&p, &m).unwrap();
        assert_eq!(back, ckpt);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_wrong_model() {
        let m = manifest();
        let ckpt = Checkpoint {
            model: "synthetic".into(),
            step: 0,
            version: 0,
            seed: 0,
            params: ParamSet::init_he(&m, &mut Pcg64::seeded(2)),
        };
        let p = tmp("wrong-model");
        ckpt.save(&p).unwrap();
        let other = Manifest::synthetic_for_tests(vec![LayerSpec { d_in: 6, d_out: 6 }]);
        assert!(Checkpoint::load(&p, &other).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_corruption() {
        let p = tmp("corrupt");
        std::fs::write(&p, b"ISSGDCKPgarbage").unwrap();
        assert!(Checkpoint::load(&p, &manifest()).is_err());
        std::fs::remove_file(&p).ok();
    }
}
