//! Runtime layer: the bridge from AOT artifacts (HLO text lowered by
//! `python/compile/aot.py`) to executable PJRT computations.
//!
//! * [`Manifest`] — parses `manifest.json`, the shape contract with the
//!   python compile path.
//! * [`Engine`] — compiles the four entry points once and exposes typed
//!   step functions (`train_step`, `grad_norms`, `eval_step`,
//!   `grad_mean_sqnorm`).  Python never runs at this point; the rust
//!   binary is self-contained.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, EvalOutput, PeerOutput, ScoreOutput, StepOutput};
pub use manifest::{LayerSpec, Manifest};

use std::path::PathBuf;

/// Locate the artifacts directory for `config`, honouring the
/// `ISSGD_ARTIFACTS` env var and falling back to `./artifacts`.
pub fn artifacts_dir(config: &str) -> PathBuf {
    let base = std::env::var("ISSGD_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    PathBuf::from(base).join(config)
}
