//! PJRT execution engine: loads the HLO-text artifacts once, compiles them
//! on the CPU PJRT client, and exposes typed step functions to the
//! coordinator.  This is the only module that touches the `xla` crate on
//! the hot path.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute`.  Outputs
//! are single tuple literals (the AOT side lowers with `return_tuple=True`)
//! decomposed with `Literal::to_tuple`.

use std::path::Path;

use anyhow::{Context, Result};
use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use super::Manifest;
use crate::model::ParamSet;

/// Result of one training step.
#[derive(Debug, Clone, Copy)]
pub struct StepOutput {
    pub loss: f32,
}

/// Result of one scoring call over a batch.
#[derive(Debug, Clone)]
pub struct ScoreOutput {
    /// Per-example squared gradient norms `||g(x_n)||^2`.
    pub sqnorms: Vec<f32>,
    /// Per-example cross-entropy losses.
    pub losses: Vec<f32>,
}

/// Result of one evaluation call over a batch.
#[derive(Debug, Clone, Copy)]
pub struct EvalOutput {
    pub sum_loss: f32,
    pub n_correct: f32,
}

/// Result of one ASGD peer step (paper §6 extension).
#[derive(Debug, Clone)]
pub struct PeerOutput {
    /// Flattened weighted gradient in layer order (W_0, b_0, ...), ready
    /// for the parameter server's `apply_grad`.
    pub grad_flat: Vec<f32>,
    pub loss: f32,
    /// Per-example squared gradient norms of the unweighted loss — the
    /// importance weights obtained "at the same time" (§6).
    pub sqnorms: Vec<f32>,
}

pub struct Engine {
    manifest: Manifest,
    #[allow(dead_code)]
    client: PjRtClient,
    train_step: Option<PjRtLoadedExecutable>,
    grad_norms: Option<PjRtLoadedExecutable>,
    peer_step: Option<PjRtLoadedExecutable>,
    eval_step: Option<PjRtLoadedExecutable>,
    grad_mean_sqnorm: Option<PjRtLoadedExecutable>,
}

impl Engine {
    const ALL_ENTRIES: &'static [&'static str] = &[
        "train_step",
        "grad_norms",
        "peer_step",
        "eval_step",
        "grad_mean_sqnorm",
    ];

    /// Load and compile all entry points of a config directory.
    pub fn load(dir: &Path) -> Result<Engine> {
        Self::load_entries(dir, Self::ALL_ENTRIES)
    }

    /// Load and compile only the named entry points (e.g. a worker only
    /// needs `grad_norms` — compiling the rest wastes startup time, and
    /// every live worker thread owns its own engine because `PjRtClient`
    /// is not `Send`).
    pub fn load_entries(dir: &Path, entries: &[&str]) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        Self::with_manifest_entries(manifest, entries)
    }

    pub fn with_manifest(manifest: Manifest) -> Result<Engine> {
        Self::with_manifest_entries(manifest, Self::ALL_ENTRIES)
    }

    pub fn with_manifest_entries(manifest: Manifest, entries: &[&str]) -> Result<Engine> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        let compile = |name: &str| -> Result<Option<PjRtLoadedExecutable>> {
            if !entries.contains(&name) {
                return Ok(None);
            }
            let path = manifest.artifact_path(name)?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))
                .map(Some)
        };
        for e in entries {
            anyhow::ensure!(Self::ALL_ENTRIES.contains(e), "unknown entry point {e:?}");
        }
        Ok(Engine {
            train_step: compile("train_step")?,
            grad_norms: compile("grad_norms")?,
            peer_step: compile("peer_step")?,
            eval_step: compile("eval_step")?,
            grad_mean_sqnorm: compile("grad_mean_sqnorm")?,
            manifest,
            client,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    // -- buffer plumbing ----------------------------------------------------
    //
    // Inputs go host -> device via `buffer_from_host_buffer` + `execute_b`.
    // Never use `execute::<Literal>` here: xla-rs 0.1.6's C++ `execute`
    // converts each input literal to a device buffer, `release()`s it and
    // never frees it — a per-call leak proportional to the argument sizes
    // (~8 MB/step for the `small` config; found the hard way, see
    // EXPERIMENTS.md §Perf).  `execute_b` leaves input ownership with us,
    // and `PjRtBuffer`'s Drop frees device memory correctly.  As a bonus
    // this path performs one host->device copy instead of literal-building
    // plus transfer.

    fn buf_2d(&self, data: &[f32], rows: usize, cols: usize) -> Result<PjRtBuffer> {
        anyhow::ensure!(
            data.len() == rows * cols,
            "buffer holds {} values, shape ({rows},{cols}) needs {}",
            data.len(),
            rows * cols
        );
        Ok(self.client.buffer_from_host_buffer(data, &[rows, cols], None)?)
    }

    fn buf_1d(&self, data: &[f32]) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, &[data.len()], None)?)
    }

    fn params_to_buffers(&self, params: &ParamSet, out: &mut Vec<PjRtBuffer>) -> Result<()> {
        anyhow::ensure!(
            params.layers.len() == self.manifest.layers.len(),
            "param set has {} layers, manifest {}",
            params.layers.len(),
            self.manifest.layers.len()
        );
        for layer in &params.layers {
            out.push(self.buf_2d(&layer.w, layer.d_in, layer.d_out)?);
            out.push(self.buf_1d(&layer.b)?);
        }
        Ok(())
    }

    fn literals_to_params(&self, literals: &[Literal]) -> Result<ParamSet> {
        let specs = &self.manifest.layers;
        anyhow::ensure!(
            literals.len() == 2 * specs.len(),
            "expected {} param literals, got {}",
            2 * specs.len(),
            literals.len()
        );
        let mut layers = Vec::with_capacity(specs.len());
        for (i, spec) in specs.iter().enumerate() {
            let w = literals[2 * i].to_vec::<f32>()?;
            let b = literals[2 * i + 1].to_vec::<f32>()?;
            anyhow::ensure!(w.len() == spec.d_in * spec.d_out && b.len() == spec.d_out);
            layers.push(crate::model::Layer {
                w,
                b,
                d_in: spec.d_in,
                d_out: spec.d_out,
            });
        }
        Ok(ParamSet { layers })
    }

    fn run(&self, exe: &PjRtLoadedExecutable, args: &[PjRtBuffer]) -> Result<Vec<Literal>> {
        let result = exe.execute_b::<PjRtBuffer>(args)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    // -- typed entry points ---------------------------------------------------

    /// One SGD step.  `x` is `(M, d)` row-major, `y` one-hot `(M, C)`,
    /// `coef` the per-slot loss coefficients (§4.1), `lr` the step size.
    /// On success `params` is replaced by the updated parameters.
    pub fn train_step(
        &self,
        params: &mut ParamSet,
        x: &[f32],
        y: &[f32],
        coef: &[f32],
        lr: f32,
    ) -> Result<StepOutput> {
        let m = self.manifest.batch_train;
        anyhow::ensure!(coef.len() == m, "coef len {} != batch {m}", coef.len());
        let mut args = Vec::with_capacity(2 * self.manifest.layers.len() + 4);
        self.params_to_buffers(params, &mut args)?;
        args.push(self.buf_2d(x, m, self.manifest.input_dim)?);
        args.push(self.buf_2d(y, m, self.manifest.n_classes)?);
        args.push(self.buf_1d(coef)?);
        args.push(self.buf_1d(&[lr])?);
        let exe = self.train_step.as_ref().context("train_step not loaded")?;
        let outputs = self.run(exe, &args)?;
        let np = 2 * self.manifest.layers.len();
        anyhow::ensure!(outputs.len() == np + 1, "train_step returned {} values", outputs.len());
        *params = self.literals_to_params(&outputs[..np])?;
        let loss = outputs[np].get_first_element::<f32>()?;
        Ok(StepOutput { loss })
    }

    /// One ASGD peer step (paper §6): returns the weighted minibatch
    /// gradient (flattened, for `WeightStore::apply_grad`) together with
    /// the per-example squared gradient norms of the unweighted loss.
    pub fn peer_step(
        &self,
        params: &ParamSet,
        x: &[f32],
        y: &[f32],
        coef: &[f32],
    ) -> Result<PeerOutput> {
        let m = self.manifest.batch_train;
        anyhow::ensure!(coef.len() == m, "coef len {} != batch {m}", coef.len());
        let mut args = Vec::with_capacity(2 * self.manifest.layers.len() + 3);
        self.params_to_buffers(params, &mut args)?;
        args.push(self.buf_2d(x, m, self.manifest.input_dim)?);
        args.push(self.buf_2d(y, m, self.manifest.n_classes)?);
        args.push(self.buf_1d(coef)?);
        let exe = self.peer_step.as_ref().context("peer_step not loaded")?;
        let outputs = self.run(exe, &args)?;
        let np = 2 * self.manifest.layers.len();
        anyhow::ensure!(
            outputs.len() == np + 2,
            "peer_step returned {} values",
            outputs.len()
        );
        let mut grad_flat = Vec::with_capacity(self.manifest.n_params);
        for lit in &outputs[..np] {
            grad_flat.extend(lit.to_vec::<f32>()?);
        }
        Ok(PeerOutput {
            grad_flat,
            loss: outputs[np].get_first_element::<f32>()?,
            sqnorms: outputs[np + 1].to_vec::<f32>()?,
        })
    }

    /// Per-example gradient norms over a scoring batch of size `batch_score`.
    pub fn grad_norms(&self, params: &ParamSet, x: &[f32], y: &[f32]) -> Result<ScoreOutput> {
        let b = self.manifest.batch_score;
        let mut args = Vec::with_capacity(2 * self.manifest.layers.len() + 2);
        self.params_to_buffers(params, &mut args)?;
        args.push(self.buf_2d(x, b, self.manifest.input_dim)?);
        args.push(self.buf_2d(y, b, self.manifest.n_classes)?);
        let exe = self.grad_norms.as_ref().context("grad_norms not loaded")?;
        let outputs = self.run(exe, &args)?;
        anyhow::ensure!(outputs.len() == 2, "grad_norms returned {} values", outputs.len());
        Ok(ScoreOutput {
            sqnorms: outputs[0].to_vec::<f32>()?,
            losses: outputs[1].to_vec::<f32>()?,
        })
    }

    /// Sum-loss and correct-count over an eval batch of size `batch_eval`.
    pub fn eval_step(&self, params: &ParamSet, x: &[f32], y: &[f32]) -> Result<EvalOutput> {
        let e = self.manifest.batch_eval;
        let mut args = Vec::with_capacity(2 * self.manifest.layers.len() + 2);
        self.params_to_buffers(params, &mut args)?;
        args.push(self.buf_2d(x, e, self.manifest.input_dim)?);
        args.push(self.buf_2d(y, e, self.manifest.n_classes)?);
        let exe = self.eval_step.as_ref().context("eval_step not loaded")?;
        let outputs = self.run(exe, &args)?;
        anyhow::ensure!(outputs.len() == 2, "eval_step returned {} values", outputs.len());
        Ok(EvalOutput {
            sum_loss: outputs[0].get_first_element::<f32>()?,
            n_correct: outputs[1].get_first_element::<f32>()?,
        })
    }

    /// `||grad of mean CE||^2` over a batch of size `batch_train` — the
    /// §B.2 estimator component for `||g_TRUE||^2`.
    pub fn grad_mean_sqnorm(&self, params: &ParamSet, x: &[f32], y: &[f32]) -> Result<f32> {
        let m = self.manifest.batch_train;
        let mut args = Vec::with_capacity(2 * self.manifest.layers.len() + 2);
        self.params_to_buffers(params, &mut args)?;
        args.push(self.buf_2d(x, m, self.manifest.input_dim)?);
        args.push(self.buf_2d(y, m, self.manifest.n_classes)?);
        let exe = self.grad_mean_sqnorm.as_ref().context("grad_mean_sqnorm not loaded")?;
        let outputs = self.run(exe, &args)?;
        anyhow::ensure!(outputs.len() == 1);
        Ok(outputs[0].get_first_element::<f32>()?)
    }
}
