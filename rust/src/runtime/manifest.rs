//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime.  One `manifest.json` per model config describes layer
//! shapes, the shape-specialised batch sizes, and the HLO artifact files.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerSpec {
    pub d_in: usize,
    pub d_out: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    /// Config name (`tiny`, `small`, `paper`, ...).
    pub config: String,
    /// Directory the manifest was loaded from (artifact files live here).
    pub dir: PathBuf,
    /// Layer widths `input -> hidden... -> classes`.
    pub dims: Vec<usize>,
    pub layers: Vec<LayerSpec>,
    pub n_params: usize,
    pub input_dim: usize,
    pub n_classes: usize,
    /// Master SGD minibatch size M.
    pub batch_train: usize,
    /// Worker scoring batch size B.
    pub batch_score: usize,
    /// Evaluation batch size E.
    pub batch_eval: usize,
    /// entry point name -> artifact file name.
    pub artifacts: Vec<(String, String)>,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        Self::from_json(&json, dir)
    }

    fn from_json(json: &Json, dir: &Path) -> Result<Manifest> {
        let dims: Vec<usize> = json
            .req_arr("dims")?
            .iter()
            .map(|v| v.as_usize().context("dims entry"))
            .collect::<Result<_>>()?;
        anyhow::ensure!(dims.len() >= 2, "need at least input+output dims");
        let layers: Vec<LayerSpec> = json
            .req_arr("layers")?
            .iter()
            .map(|l| {
                let w = l.req_arr("w_shape")?;
                anyhow::ensure!(w.len() == 2, "w_shape must be 2-d");
                Ok(LayerSpec {
                    d_in: w[0].as_usize().context("w_shape[0]")?,
                    d_out: w[1].as_usize().context("w_shape[1]")?,
                })
            })
            .collect::<Result<_>>()?;
        anyhow::ensure!(layers.len() == dims.len() - 1, "layer count mismatch");
        let artifacts = json
            .get("artifacts")
            .and_then(Json::as_obj)
            .context("missing artifacts object")?
            .iter()
            .map(|(name, spec)| Ok((name.clone(), spec.req_str("file")?.to_string())))
            .collect::<Result<Vec<_>>>()?;
        let m = Manifest {
            config: json.req_str("config")?.to_string(),
            dir: dir.to_path_buf(),
            n_params: json.req_usize("n_params")?,
            input_dim: json.req_usize("input_dim")?,
            n_classes: json.req_usize("n_classes")?,
            batch_train: json.req_usize("batch_train")?,
            batch_score: json.req_usize("batch_score")?,
            batch_eval: json.req_usize("batch_eval")?,
            dims,
            layers,
            artifacts,
        };
        // Cross-validate the parameter count against layer shapes.
        let computed: usize = m.layers.iter().map(|l| l.d_in * l.d_out + l.d_out).sum();
        anyhow::ensure!(
            computed == m.n_params,
            "n_params {} disagrees with layer shapes {}",
            m.n_params,
            computed
        );
        anyhow::ensure!(m.input_dim == m.dims[0], "input_dim/dims mismatch");
        anyhow::ensure!(
            m.n_classes == *m.dims.last().unwrap(),
            "n_classes/dims mismatch"
        );
        Ok(m)
    }

    /// Absolute path of an artifact by entry-point name.
    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        let file = self
            .artifacts
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, f)| f)
            .with_context(|| format!("manifest has no artifact {name:?}"))?;
        Ok(self.dir.join(file))
    }

    /// A manifest not backed by files — for unit tests of components that
    /// only need shapes (e.g. `ParamSet`).
    pub fn synthetic_for_tests(layers: Vec<LayerSpec>) -> Manifest {
        let mut dims = vec![layers[0].d_in];
        dims.extend(layers.iter().map(|l| l.d_out));
        let n_params = layers.iter().map(|l| l.d_in * l.d_out + l.d_out).sum();
        Manifest {
            config: "synthetic".into(),
            dir: PathBuf::new(),
            input_dim: dims[0],
            n_classes: *dims.last().unwrap(),
            dims,
            layers,
            n_params,
            batch_train: 8,
            batch_score: 16,
            batch_eval: 16,
            artifacts: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "config": "tiny",
        "dims": [64, 32, 32, 10],
        "dtype": "f32",
        "n_classes": 10,
        "input_dim": 64,
        "n_layers": 3,
        "n_params": 3466,
        "layers": [
            {"w_shape": [64, 32], "b_shape": [32]},
            {"w_shape": [32, 32], "b_shape": [32]},
            {"w_shape": [32, 10], "b_shape": [10]}
        ],
        "batch_train": 8,
        "batch_score": 16,
        "batch_eval": 16,
        "artifacts": {
            "train_step": {"file": "train_step.hlo.txt", "sha256": "x", "bytes": 1},
            "grad_norms": {"file": "grad_norms.hlo.txt", "sha256": "x", "bytes": 1}
        },
        "calling_convention": "flat-params-first"
    }"#;

    #[test]
    fn parses_sample() {
        let json = Json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(&json, Path::new("/art/tiny")).unwrap();
        assert_eq!(m.config, "tiny");
        assert_eq!(m.layers.len(), 3);
        assert_eq!(m.layers[0], LayerSpec { d_in: 64, d_out: 32 });
        assert_eq!(m.n_params, 3466);
        assert_eq!(
            m.artifact_path("train_step").unwrap(),
            Path::new("/art/tiny/train_step.hlo.txt")
        );
        assert!(m.artifact_path("nope").is_err());
    }

    #[test]
    fn rejects_inconsistent_param_count() {
        let bad = SAMPLE.replace("3466", "9999");
        let json = Json::parse(&bad).unwrap();
        assert!(Manifest::from_json(&json, Path::new("/x")).is_err());
    }

    #[test]
    fn synthetic_counts() {
        let m = Manifest::synthetic_for_tests(vec![
            LayerSpec { d_in: 4, d_out: 2 },
            LayerSpec { d_in: 2, d_out: 3 },
        ]);
        assert_eq!(m.dims, vec![4, 2, 3]);
        assert_eq!(m.n_params, 4 * 2 + 2 + 2 * 3 + 3);
    }
}
